"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map +
ppermute (stage-to-stage sends are point-to-point ICI transfers).

Stages hold disjoint layer blocks (stage_params leading dim sharded over
the pipeline axis). Microbatches stream through; JAX AD differentiates
through the ppermute ring (its transpose is the reverse permute), so the
same function trains. Combine with DP/TP on the remaining mesh axes:
e.g. mesh (pod=2, data=16, model=16) -> 2 pipeline stages x 16-way fsdp
x 16-way TP.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

try:                                    # jax >= 0.6 public location
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_rep)
except (ImportError, TypeError):
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep)


def pipeline_apply(mesh: Mesh, axis: str, stage_fn: Callable,
                   stage_params, microbatches: jnp.ndarray) -> jnp.ndarray:
    """Run `microbatches` (n_micro, mb, ...) through `n_stages` pipeline
    stages. stage_params: pytree with leading dim n_stages (one slice per
    stage). stage_fn(params_slice, x) -> y must preserve x's shape.

    Returns outputs (n_micro, mb, ...) — activations after the last stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    T = n_micro + n_stages - 1
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def inner(params, mbs):
        params = jax.tree.map(lambda p: p[0], params)   # local stage slice
        idx = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(mbs[0])
        outs = jnp.zeros_like(mbs)

        def tick(t, carry):
            buf, outs = carry
            inject = mbs[jnp.clip(t, 0, n_micro - 1)]
            x = jnp.where(idx == 0, inject, buf)
            y = stage_fn(params, x)
            # the LAST stage's result at tick t is microbatch t-(S-1)
            out_idx = t - (n_stages - 1)
            upd = jax.lax.dynamic_update_slice_in_dim(
                outs, y[None].astype(outs.dtype), jnp.clip(out_idx, 0, n_micro - 1), 0)
            outs = jnp.where((idx == n_stages - 1) & (out_idx >= 0), upd, outs)
            buf = jax.lax.ppermute(y, axis, ring)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        return outs[None]   # (1, n_micro, ...) per stage

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    out = shard_map(inner, mesh,
                    in_specs=(spec_p, P()), out_specs=P(axis))(
        stage_params, microbatches)
    return out[-1]          # last stage's buffer holds the real outputs


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    def re(p):
        L = p.shape[0]
        assert L % n_stages == 0, f"{L} layers not divisible by {n_stages} stages"
        return p.reshape(n_stages, L // n_stages, *p.shape[1:])

    return jax.tree.map(re, stacked_params)
