"""Distributed-optimization collectives.

``compress_grads_int8``: int8-quantized gradient representation with error
feedback — halving (vs bf16) / quartering (vs f32) gradient all-reduce
volume. Under GSPMD the all-reduce happens on the quantized tensor when the
cast brackets the psum; we expose both a GSPMD-friendly cast pattern and an
explicit shard_map ring variant for measurement.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads_int8(grads):
    """Per-leaf int8 quantize->dequantize (error bounded by 1/254 of max).
    Placed before the (GSPMD-inserted) gradient all-reduce so the collective
    moves int8 data after XLA fuses the casts."""
    def comp(g):
        if g.ndim == 0 or g.size < 4096:
            return g
        q, s = quantize_int8(g)
        return dequantize_int8(q, s).astype(g.dtype)

    return jax.tree.map(comp, grads)


def psum_int8(x, axis_name: str):
    """Explicit compressed all-reduce inside shard_map: quantize, psum the
    int8 payload widened to int32 (exact), dequantize with a psum'd scale."""
    q, s = quantize_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s_max = jax.lax.pmax(s, axis_name)
    return total.astype(jnp.float32) * s_max


def ring_allreduce_int8(mesh, axis: str):
    """shard_map wrapper: compressed all-reduce of a pytree over `axis`."""
    from jax.experimental.shard_map import shard_map

    def fn(tree):
        def one(x):
            return psum_int8(x, axis)

        return jax.tree.map(one, tree)

    def call(tree):
        specs = jax.tree.map(lambda _: P(), tree)
        return shard_map(fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
                         check_rep=False)(tree)

    return call
