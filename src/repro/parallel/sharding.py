"""Sharding recipes: map every param/input/cache leaf to a PartitionSpec.

Recipes (DESIGN.md §5):
  fsdp_tp   — train default. TP dim over `model`; the other matmul dim over
              the data axes (ZeRO-3); batch over data axes.
  dp_tp     — replicated weights + TP; batch over data axes (small models).
  tp_serve  — decode: weights TP over `model` only (replicated over data);
              batch over data; KV-cache *sequence* over `model`
              (flash-decode SP: softmax reductions psum over `model`).
  tp2d_serve— decode for models too big to replicate over data: weights 2D
              (d over data axes, heads/ff over model); cache batch over
              data, sequence over model; activation reshards are
              decode-sized (tiny).

Rules are applied by *leaf path suffix* and aligned to the trailing dims of
each leaf, so stacked layouts ((L, ...), (g, r, ...), …) inherit the same
rule with leading scan dims replicated.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return int(np.prod([mesh.shape[a] for a in axes]))


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def pick_recipe(cfg: ModelConfig, shape: ShapeConfig) -> str:
    big = cfg.n_params() * 2 > 12e9   # bf16 bytes vs ~12GB budget/chip
    if shape.kind == "train":
        return "fsdp_tp" if cfg.n_params() * 2 > 1e9 else "dp_tp"
    if shape.kind == "prefill":
        return "fsdp_tp" if big else "dp_tp"
    return "tp2d_serve" if big else "tp_serve"


# --------------------------------------------------------------------- #
#  Parameter rules                                                       #
# --------------------------------------------------------------------- #
def _param_rule(path: str, cfg: ModelConfig, recipe: str, mesh: Mesh,
                ndim: int):
    """Returns a tuple spec for the TRAILING dims of the leaf."""
    d = data_axes_of(mesh)
    fsdp = d if recipe == "fsdp_tp" else (d if recipe == "tp2d_serve" else None)
    m = "model" if "model" in mesh.axis_names else None

    def rule():
        # ---- embeddings ----
        if path.endswith("embed/embedding"):
            return (m, fsdp)                      # (V, d)
        if path.endswith("embed/unembed"):
            return (fsdp, m)                      # (d, V)
        # ---- attention ----
        if re.search(r"(attn|xattn)/w[kv]$", path):
            # shard kv heads only when they divide the model axis (else the
            # flat (KVH*hd) shard would split a head: forced reshards)
            ok = m and cfg.attn.n_kv_heads % mesh.shape["model"] == 0
            return (fsdp, m if ok else None)
        if re.search(r"(attn|xattn)/wq$", path):
            return (fsdp, m)                      # (d_in, heads*hd)
        if re.search(r"(attn|xattn)/wo$", path):
            return (m, fsdp)                      # (heads*hd, d)
        if re.search(r"/(q_norm|k_norm|gate)$", path) and not path.endswith("w_gate"):
            return ()
        # ---- MoE ----
        if "moe/router" in path:
            return (None, None)
        if re.search(r"moe/w_(gate|up)$", path):
            return (m, fsdp, None)                # (E, d, f)
        if path.endswith("moe/w_down"):
            return (m, None, fsdp)                # (E, f, d)
        if re.search(r"shared/w_(gate|up)$", path):
            return (fsdp, m)
        if path.endswith("shared/w_down"):
            return (m, fsdp)
        # ---- dense MLP ----
        if re.search(r"mlp/w_(gate|up)$", path):
            return (fsdp, m)
        if path.endswith("mlp/w_down"):
            return (m, fsdp)
        # ---- mamba (1 & 2) ----
        if re.search(r"mixer/in_[xz]$", path):
            return (fsdp, m)                      # (d, di) channels TP
        if path.endswith("mixer/x_proj"):
            return (m, None)                      # (di, r+2N)
        if path.endswith("mixer/dt_proj"):
            return (None, m)                      # (r, di)
        if path.endswith("mixer/A_log") and ndim >= 2 and cfg.ssm.variant == "mamba1":
            return (m, None)                      # (di, N)
        # ---- mamba2 ----
        if path.endswith("mixer/in_dt"):
            return (fsdp, m)
        if path.endswith("mixer/in_bc"):
            return (fsdp, None)
        if path.endswith("mixer/conv_x_w") or path.endswith("mixer/conv_w"):
            return (m, None)                      # (di, K)
        if re.search(r"mixer/conv_(x_)?b$", path):
            return (m,)
        if path.endswith("mixer/conv_bc_w"):
            return (None, None)
        if path.endswith("mixer/conv_bc_b"):
            return (None,)
        if re.search(r"mixer/(A_log|D|dt_bias)$", path):
            return (m,)                           # (di,) or (H,)
        if path.endswith("mixer/norm/scale"):
            return (m,)                           # (di,) gated-norm scale
        if path.endswith("mixer/out_proj"):
            return (m, fsdp)                      # (di, d)
        # ---- norms & rest ----
        if path.endswith("scale"):
            return (None,)
        return None                               # replicate fully

    r = rule()
    if r is None:
        return P()
    # canonicalize 1-axis tuples (('data',) -> 'data'): same GSPMD
    # sharding, but comparable against hand-written specs
    r = tuple(ax[0] if isinstance(ax, tuple) and len(ax) == 1 else ax
              for ax in r)
    assert len(r) <= ndim, f"{path}: rule {r} longer than ndim {ndim}"
    return P(*((None,) * (ndim - len(r)) + r))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop axes from dims they don't evenly divide (e.g. vocab=504,
    batch=1): divisibility is required for clean GSPMD partitioning."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None:
            out.append(None)
        elif shape[i] % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def param_specs(cfg: ModelConfig, recipe: str, mesh: Mesh, params_shape):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    def spec(kp, leaf):
        s = _param_rule(_path_str(kp), cfg, recipe, mesh, len(leaf.shape))
        return sanitize(s, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


# --------------------------------------------------------------------- #
#  Batch / cache rules                                                   #
# --------------------------------------------------------------------- #
def batch_specs(cfg: ModelConfig, recipe: str, mesh: Mesh, kind: str):
    d = data_axes_of(mesh)
    if kind == "decode":
        tok = P(d)            # (B, 1)
    else:
        tok = P(d, None)      # (B, S)
    specs = {"tokens": tok, "labels": tok}
    if cfg.family == "audio":
        specs["frames"] = P(d, None, None)
        specs.pop("tokens")
    if cfg.family == "vlm":
        specs["vision"] = P(d, None, None)
    return specs


def cache_specs(cfg: ModelConfig, recipe: str, mesh: Mesh, cache_shape,
                seq_axis_shards: Optional[str] = "model"):
    """KV caches: batch over data axes, sequence over `model` (SP decode).
    SSM states: batch over data, channels/heads over `model`."""
    d = data_axes_of(mesh)
    m = seq_axis_shards if "model" in mesh.axis_names else None

    def spec(kp, leaf):
        path = _path_str(kp)
        nd = len(leaf.shape)

        def trail(r):
            s = P(*((None,) * (nd - len(r)) + tuple(r)))
            s = sanitize(s, leaf.shape, mesh)
            # long-context fallback: batch too small to shard -> put the
            # sequence dim over data axes too (SP over the whole mesh)
            if (r and r[0] == d and s[nd - len(r)] is None and len(r) >= 4
                    and m is not None):
                seq_i = nd - len(r) + 1
                if leaf.shape[seq_i] % (_axis_size(mesh, d) * _axis_size(mesh, m)) == 0:
                    full = list(s)
                    full[seq_i] = tuple(d) + ("model",)
                    s = P(*full)
            return s

        if re.search(r"(^|/)(k|v|global_k|global_v|attn_k|attn_v)$", path):
            return trail((d, m, None, None))          # (..., B, S, KVH, D)
        if re.search(r"(local_k|local_v|tail_k|tail_v)$", path):
            return trail((d, None, None, None))       # ring window unsharded
        if re.search(r"cross_(k|v)$", path):
            return trail((d, None, None, None))
        if path.endswith("h") and cfg.ssm.variant == "mamba1":
            return trail((d, "model", None))           # (..., B, di, N)
        if path.endswith("h"):
            return trail((d, "model", None, None))     # (..., B, H, P, N)
        if path.endswith("conv_x") or path.endswith("conv"):
            return trail((d, None, "model"))
        if path.endswith("conv_bc"):
            return trail((d, None, None))
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def sanitize_tree(spec_tree, shape_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, l: sanitize(s, l.shape, mesh), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
