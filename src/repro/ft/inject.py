"""Deterministic fault injection for the fleet scheduler.

The harness makes failure handling a *gated, replayable* property instead
of a hope: a scripted trace of device kills, slow devices, arrival storms
and departures runs against a ``FleetScheduler`` on a virtual clock
(``FakeClock``), so every test and benchmark sees the exact same event
timeline — no sleeps, no wall-clock flake, bit-identical decision logs.

Pieces
  * ``FakeClock`` — a callable monotonic clock with ``advance(dt)``;
    drop-in for the ``clock=`` parameter of ``HeartbeatTracker``,
    ``StragglerMonitor``, and ``FleetScheduler``.
  * ``InjectEvent`` + builders (``arrive``/``storm``/``depart``/``kill``/
    ``slow``) — the scripted trace vocabulary.
  * ``FaultInjector`` — the event-loop driver: each virtual tick it
    applies due events, emits heartbeats for every live (non-killed)
    device, calls ``fleet.tick()``, and advances the clock.  A killed
    device simply STOPS BEATING — death is *detected* by the fleet's
    heartbeat timeout, exactly like a real lost host, not short-circuited
    through a private API.

The injector is duck-typed against the fleet (``submit`` / ``remove`` /
``heartbeat`` / ``observe_step`` / ``tick`` / ``devices``) so this module
has no import cycle with ``repro.core.fleet``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence


class FakeClock:
    """Deterministic virtual clock: ``clock()`` reads, ``advance`` steps.

    Monotonic by construction — ``advance`` rejects negative steps — so
    code written against ``time.monotonic`` behaves identically on it.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot go backwards (dt={dt})")
        self._t += float(dt)
        return self._t

    def __repr__(self):
        return f"<FakeClock t={self._t:.3f}>"


@dataclass(frozen=True)
class InjectEvent:
    """One scripted event: fires the first tick whose time reaches ``t``.

    kinds: "arrive" (workload, priority, train_meta), "depart" (name),
    "kill" (device), "revive" (device), "slow" (device, baseline,
    factor, steps).
    """
    t: float
    kind: str
    payload: dict = field(default_factory=dict)


def arrive(t: float, workload, priority: str = "slo",
           train_meta: Optional[dict] = None) -> InjectEvent:
    return InjectEvent(t, "arrive", {"workload": workload,
                                     "priority": priority,
                                     "train_meta": train_meta})


def storm(t: float, workloads: Sequence, priority: str = "best_effort"
          ) -> List[InjectEvent]:
    """An arrival storm: every workload lands on the SAME tick (admission
    control must bound the queue instead of growing without limit)."""
    return [arrive(t, w, priority) for w in workloads]


def depart(t: float, name: str) -> InjectEvent:
    return InjectEvent(t, "depart", {"name": name})


def kill(t: float, device: str) -> InjectEvent:
    """Device failure: the device stops heartbeating at ``t``; the fleet
    declares it dead once the heartbeat timeout elapses."""
    return InjectEvent(t, "kill", {"device": device})


def revive(t: float, device: str) -> InjectEvent:
    """The host comes back: the device resumes heartbeating at ``t``.
    If the fleet already declared it dead, the next beat revives it
    (a capacity-scoped replan re-places waiting workloads)."""
    return InjectEvent(t, "revive", {"device": device})


def slow(t: float, device: str, baseline: float = 1.0, factor: float = 8.0,
         steps: int = 6) -> InjectEvent:
    """Straggling device: feeds ``steps`` baseline step-times followed by
    two ``baseline * factor`` outliers into the device's
    ``StragglerMonitor`` (enough to pass warmup and trip detection)."""
    return InjectEvent(t, "slow", {"device": device, "baseline": baseline,
                                   "factor": factor, "steps": steps})


class FaultInjector:
    """Replay a scripted trace against a fleet on a virtual clock.

    >>> clock = FakeClock()
    >>> fleet = FleetScheduler(devices, config, clock=clock)
    >>> FaultInjector(fleet, clock).run(trace, until=30.0)

    Each tick (``tick_dt`` virtual seconds):
      1. apply every event with ``event.t <= now`` (script insertion
         order breaks ties — storms stay ordered);
      2. heartbeat every device that has not been killed;
      3. ``fleet.tick()`` (heartbeat scan, retries, replanning);
      4. optional ``on_tick(fleet, now)`` observation hook;
      5. advance the clock.

    The injector never raises out of ``run`` for *fleet*-side refusals
    (that is the fleet's own no-crash contract); script errors (unknown
    event kind, departing a never-arrived name) do raise — a broken
    script is a test bug, not a fault to tolerate.
    """

    def __init__(self, fleet, clock: FakeClock, tick_dt: float = 1.0,
                 on_tick: Optional[Callable] = None):
        self.fleet = fleet
        self.clock = clock
        self.tick_dt = float(tick_dt)
        self.on_tick = on_tick
        self.killed: set = set()
        self.applied: List[InjectEvent] = []
        self._step_no: Dict[str, int] = {}

    # ------------------------------------------------------------- #
    def _apply(self, ev: InjectEvent) -> None:
        p = ev.payload
        if ev.kind == "arrive":
            self.fleet.submit(p["workload"], priority=p["priority"],
                              train_meta=p.get("train_meta"))
        elif ev.kind == "depart":
            self.fleet.remove(p["name"])
        elif ev.kind == "kill":
            self.killed.add(p["device"])
        elif ev.kind == "revive":
            self.killed.discard(p["device"])
            self.fleet.heartbeat(p["device"])
        elif ev.kind == "slow":
            dev = p["device"]
            n0 = self._step_no.get(dev, 0)
            dts = [p["baseline"]] * p["steps"] + \
                  [p["baseline"] * p["factor"]] * 2
            for i, dt in enumerate(dts):
                self.fleet.observe_step(dev, n0 + i, dt)
            self._step_no[dev] = n0 + len(dts)
        else:
            raise ValueError(f"unknown inject event kind: {ev.kind!r}")
        self.applied.append(ev)

    def run(self, trace: Sequence[InjectEvent], until: Optional[float] = None):
        """Run the trace to completion (plus ``until`` extra settle time —
        recovery needs ticks after the last scripted event: heartbeat
        timeouts must elapse and retry backoffs must fire).

        A contiguous run of 2+ due "arrive" events (an arrival storm) is
        admitted through ``fleet.submit_many`` as ONE batched replay when
        the fleet provides it, instead of replanning per arrival.
        """
        pending = sorted(enumerate(trace), key=lambda it: (it[1].t, it[0]))
        pending = [ev for _, ev in pending]
        end = max([until or 0.0] + [ev.t for ev in pending])
        submit_many = getattr(self.fleet, "submit_many", None)
        head = 0
        while head < len(pending) or self.clock() <= end:
            now = self.clock()
            while head < len(pending) and pending[head].t <= now:
                ev = pending[head]
                j = head + 1
                if ev.kind == "arrive" and submit_many is not None:
                    while (j < len(pending) and pending[j].t <= now
                           and pending[j].kind == "arrive"):
                        j += 1
                if j - head > 1:        # storm: one deduplicated replay
                    batch = pending[head:j]
                    submit_many([(e.payload["workload"],
                                  e.payload["priority"],
                                  e.payload.get("train_meta"))
                                 for e in batch])
                    self.applied.extend(batch)
                else:
                    self._apply(ev)
                head = j
            for did in self.fleet.devices:
                if did not in self.killed:
                    self.fleet.heartbeat(did)
            self.fleet.tick()
            if self.on_tick is not None:
                self.on_tick(self.fleet, now)
            self.clock.advance(self.tick_dt)
        return self.fleet
