"""Fault-tolerance runtime pieces: straggler detection, failure-domain
heartbeats, and elastic-rescale planning.

On a real multi-pod deployment these hook into the cluster manager; the
logic (detection thresholds, rescale math, checkpoint-driven recovery
protocol) is host-side Python and identical at any scale, so it is fully
implemented and tested here.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class StragglerMonitor:
    """EWMA step-time watchdog (synchronous-SPMD straggler mitigation:
    detect, log, and trigger a rebalance/replace hook).

    ``clock`` stamps detection events; it defaults to ``time.monotonic``
    (wall-clock ``time.time`` would let NTP jumps skew event timelines)
    and is injectable so tests and the fault-injection harness
    (repro.ft.inject) run on a deterministic virtual clock.
    """

    def __init__(self, factor: float = 3.0, alpha: float = 0.2,
                 warmup: int = 3, on_straggle: Optional[Callable] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup
        self.on_straggle = on_straggle
        self.clock = clock
        self.ewma: Optional[float] = None
        self.n = 0
        self.events: List[Dict] = []

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        straggling = (self.n > self.warmup and dt > self.factor * self.ewma)
        if straggling:
            self.events.append({"step": step, "dt": dt, "ewma": self.ewma,
                                "time": self.clock()})
            if self.on_straggle:
                self.on_straggle(step, dt, self.ewma)
        else:
            # only healthy steps update the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return straggling


@dataclass
class Heartbeat:
    worker: str
    last_seen: float


class HeartbeatTracker:
    """Failure detection across workers (hosts report; controller scans).

    Timeout math runs on ``clock`` — ``time.monotonic`` by default, so an
    NTP step on the controller can never mass-declare workers dead — and
    the clock is injectable (tests / repro.ft.inject pass a virtual
    clock, so no test ever sleeps).  An explicit ``now`` always wins,
    including ``now=0.0`` (the old ``now or time.time()`` treated a zero
    timestamp as "unset").
    """

    def __init__(self, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.beats: Dict[str, Heartbeat] = {}

    def beat(self, worker: str, now: Optional[float] = None):
        self.beats[worker] = Heartbeat(
            worker, self.clock() if now is None else now)

    def forget(self, worker: str) -> None:
        """Stop tracking a worker (it was drained/decommissioned, not
        lost): it must no longer show up in ``dead_workers``."""
        self.beats.pop(worker, None)

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = self.clock() if now is None else now
        return [w for w, h in self.beats.items()
                if now - h.last_seen > self.timeout]


@dataclass
class RescalePlan:
    old_shape: Dict[str, int]
    new_shape: Dict[str, int]
    new_global_batch: int
    new_microbatches: int
    lr_scale: float
    restart_step: int

    @property
    def new_chip_count(self) -> int:
        return math.prod(self.new_shape.values())


def plan_rescale(old_shape: Dict[str, int], lost_chips: int,
                 global_batch: int, num_microbatches: int,
                 current_step: int) -> RescalePlan:
    """Elastic rescale after losing chips: shrink the data axis to the
    largest feasible size, keep global batch (more grad accum), resume
    from the last checkpoint. Checkpoints are mesh-free (repro.checkpoint)
    so re-sharding is a restore-time device_put."""
    old_chips = math.prod(old_shape.values())
    target = old_chips - lost_chips
    new_shape = dict(old_shape)
    # shed entire data-axis rows (model axis must stay intact for TP)
    while math.prod(new_shape.values()) > target and new_shape.get("data", 1) > 1:
        new_shape["data"] //= 2
    if "pod" in new_shape and math.prod(new_shape.values()) > target:
        new_shape["pod"] = max(1, new_shape["pod"] - 1)
    new_chips = math.prod(new_shape.values())
    scale = new_chips / old_chips
    new_mb = max(1, int(round(num_microbatches / scale)))
    return RescalePlan(old_shape, new_shape, global_batch, new_mb,
                       lr_scale=1.0, restart_step=current_step)
