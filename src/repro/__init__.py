"""repro: interference-aware multi-pod JAX training/serving framework.

Reproduction of "Understanding GPU Resource Interference One Level Deeper"
(SoCC'25), adapted to TPU. See DESIGN.md.
"""
__version__ = "0.1.0"
