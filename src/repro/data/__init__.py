"""Deterministic, seekable synthetic data pipeline.

Production properties required at 1000-node scale:
  * per-host sharding: each host materializes only its batch shard;
  * exactly seekable by step (restart/elastic-rescale resume is exact);
  * background prefetch (double buffering) so input never blocks TPUs;
  * sequence packing for variable-length documents.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataConfig:
    seq_len: int = 512
    global_batch: int = 8
    vocab_size: int = 256
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    mean_doc_len: int = 192     # for packing


class SyntheticLM:
    """Zipf-distributed token stream with Markov structure, packed into
    fixed-length rows. ``seek(step)`` is O(1): the RNG is keyed by
    (seed, step, host) so any step can be regenerated exactly."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.d = dcfg
        assert dcfg.global_batch % dcfg.n_hosts == 0
        self.host_batch = dcfg.global_batch // dcfg.n_hosts
        self._step = 0

    def seek(self, step: int):
        self._step = step

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.d.seed, step, self.d.host_id]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S, V = self.host_batch, self.d.seq_len, self.d.vocab_size
        # packed documents: boundaries reset the "Markov" state
        zipf = np.minimum(rng.zipf(1.3, size=(B, S + 1)), V - 1).astype(np.int32)
        drift = np.cumsum(rng.integers(0, 3, size=(B, S + 1)), axis=1)
        tokens = ((zipf + drift) % V).astype(np.int32)
        doc_len = max(8, self.d.mean_doc_len)
        boundaries = (np.arange(S + 1)[None, :] % doc_len) == 0
        loss_mask = np.broadcast_to(~boundaries[:, 1:], (B, S)
                                    ).astype(np.float32)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:],
                 "loss_mask": loss_mask}
        if self.cfg.family == "vlm":
            batch["vision"] = rng.standard_normal(
                (B, self.cfg.n_vision_tokens, self.cfg.d_vision)
            ).astype(np.float32)
        if self.cfg.family == "audio":
            batch.pop("tokens")
            batch["frames"] = rng.standard_normal(
                (B, S, self.cfg.d_model)).astype(np.float32)
            batch["labels"] = rng.integers(
                0, self.cfg.vocab_size, size=(B, S)).astype(np.int32)
        return batch

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self._step)
        self._step += 1
        return b


class Prefetcher:
    """Background-thread double buffering around any seekable source."""

    def __init__(self, source, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self.source:
                if self._stop.is_set():
                    return
                self.q.put(item)
        finally:
            self.q.put(None)

    def seek(self, step: int):
        # drain + reposition (used on restart)
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
        self.source.seek(step)
        self._stop = threading.Event()
        self.q = queue.Queue(maxsize=2)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
