"""Paged KV-cache manager for continuous batching.

Host-side block allocator (vLLM-style block tables) over a fixed device
cache of shape (L, B_slots, S_max, KVH, D). Sequences claim a slot row;
the allocator tracks per-sequence lengths, admission, and eviction. The
device-side cache layout matches repro.models.model.init_cache so the
same decode_step executes both in the engine and the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Sequence:
    seq_id: int
    prompt_len: int
    max_new: int
    slot: int = -1
    pos: int = 0                 # next position to write
    done: bool = False
    tokens: List[int] = field(default_factory=list)
    arrival: float = 0.0
    first_token_time: Optional[float] = None


class SlotAllocator:
    """Fixed-slot KV cache rows + admission control."""

    def __init__(self, n_slots: int, max_len: int):
        self.n_slots = n_slots
        self.max_len = max_len
        self.free: List[int] = list(range(n_slots))
        self.active: Dict[int, Sequence] = {}

    def can_admit(self, seq: Sequence) -> bool:
        return bool(self.free) and seq.prompt_len + seq.max_new <= self.max_len

    def admit(self, seq: Sequence) -> int:
        if not self.can_admit(seq):
            raise RuntimeError(
                f"cannot admit seq {seq.seq_id}: "
                f"{len(self.free)} free slots, needs "
                f"{seq.prompt_len + seq.max_new} <= max_len={self.max_len}")
        seq.slot = self.free.pop()
        seq.pos = 0
        self.active[seq.seq_id] = seq
        return seq.slot

    def release(self, seq_id: int):
        seq = self.active.pop(seq_id, None)
        if seq is None:
            raise KeyError(f"release of unknown/already-released seq "
                           f"{seq_id}")
        self.free.append(seq.slot)
        seq.slot = -1

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_slots

    def active_slots(self) -> np.ndarray:
        return np.array(sorted(s.slot for s in self.active.values()),
                        dtype=np.int32)
