"""Continuous-batching serving engine with interference-aware scheduling.

The paper's findings drive the scheduler:
  * takeaway §4.2 (HOL blocking): a monolithic prefill blocks the decode
    batch for its whole duration — the engine CHUNKS prefills and
    interleaves chunks between decode steps at per-kernel granularity;
  * §5.1 (estimator-driven decisions): each step the engine predicts the
    decode batch's TBT inflation from colocating one more prefill chunk
    (analytic resource profiles through repro.core.estimator) and sizes
    the chunk to keep predicted TBT within the SLO.

Supported families: uniform-attention decoders (dense/moe). The engine
runs the same jitted decode/extend steps the dry-run lowers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (TPU_V5E, DeviceModel, KernelProfile, Scenario,
                        solve_scenarios)
from repro.core.resources import RESOURCE_AXES
from repro.models import LOCAL_CTX, ParallelContext, build_model
from repro.models import transformer as tfm
from repro.models.layers import rmsnorm, unembed, embed
from repro.serve.kvcache import Sequence, SlotAllocator


_MIN_CHUNK = 16      # smallest prefill chunk the scheduler will schedule


@dataclass
class EngineConfig:
    max_slots: int = 8
    max_len: int = 512
    prefill_chunk: int = 128          # max chunk; scheduler may shrink it
    tbt_slo_ms: float = 50.0
    mode: str = "interference_aware"  # | "serial" | "fixed_chunk"
    temperature: float = 0.0
    seed: int = 0


@dataclass
class StepEvent:
    kind: str                  # "decode" | "prefill_chunk" | "admit" |
                               # "finish" | "degraded" | "recovered"
    t: float
    detail: dict = field(default_factory=dict)


class Engine:
    def __init__(self, cfg: ModelConfig, params=None, ecfg: EngineConfig = None,
                 ctx: ParallelContext = LOCAL_CTX, dev: DeviceModel = TPU_V5E,
                 key=None):
        assert cfg.family in ("dense", "moe") and cfg.attn.pattern == "global", \
            "engine supports uniform-attention decoders"
        self.cfg = cfg
        self.ecfg = ecfg or EngineConfig()
        self.ctx = ctx
        self.dev = dev
        self.model = build_model(cfg)
        key = key if key is not None else jax.random.PRNGKey(self.ecfg.seed)
        self.params = params if params is not None else self.model.init(key)
        self.alloc = SlotAllocator(self.ecfg.max_slots, self.ecfg.max_len)
        # +1 trash position: idle slots in the static decode batch write
        # their (ignored) k/v there instead of corrupting position 0
        self.cache = self.model.init_cache(self.ecfg.max_slots,
                                           self.ecfg.max_len + 1)
        self.waiting: List[Sequence] = []
        self.events: List[StepEvent] = []
        self.metrics: Dict[int, dict] = {}
        self._next_id = 0
        self.degraded = False
        self._build_steps()

    def set_degraded(self, flag: bool, reason: str = "") -> None:
        """Fleet hook: the engine's device is oversubscribed (straggling,
        or absorbing migrated work after a fleet failure).  In degraded
        mode the chunk scheduler stops spending headroom on large prefill
        chunks and always takes the minimum-predicted-TBT candidate —
        prefills slow down, decode TBT is protected."""
        if flag != self.degraded:
            self.degraded = flag
            self.events.append(StepEvent(
                "degraded" if flag else "recovered",
                time.perf_counter(), {"reason": reason}))

    # ------------------------------------------------------------- #
    def _build_steps(self):
        model, cfg, ctx = self.model, self.cfg, self.ctx

        def decode(params, tokens, cache, pos_vec):
            logits, cache = model.decode_step(params, tokens, cache, pos_vec,
                                              ctx)
            return logits, cache

        def extend(params, tokens, cache, slot, pos0):
            x = embed(params["embed"], tokens, scale_by_dim=cfg.embed_scale)
            ck = jax.lax.dynamic_slice_in_dim(cache["k"], slot, 1, axis=1)
            cv = jax.lax.dynamic_slice_in_dim(cache["v"], slot, 1, axis=1)
            x, ck, cv = tfm.uniform_stack_extend(
                params["stack"], cfg, x, ck, cv, pos0, ctx=ctx)
            cache = dict(cache,
                         k=jax.lax.dynamic_update_slice_in_dim(
                             cache["k"], ck, slot, axis=1),
                         v=jax.lax.dynamic_update_slice_in_dim(
                             cache["v"], cv, slot, axis=1))
            x = rmsnorm(params["final_ln"], x[:, -1:], cfg.norm_eps)
            return unembed(params["embed"], x), cache

        self._decode = jax.jit(decode, donate_argnums=(2,))
        self._extend = jax.jit(extend, donate_argnums=(2,))

    # ------------------------------------------------------------- #
    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        seq = Sequence(self._next_id, len(prompt), max_new,
                       tokens=list(prompt), arrival=time.perf_counter())
        self._next_id += 1
        self.waiting.append(seq)
        return seq.seq_id

    # --------------------- interference model --------------------- #
    def _phase_profile(self, name: str, n_tokens: float) -> KernelProfile:
        """Analytic per-call resource vector for one engine phase: weight
        reads dominate decode; matmul FLOPs dominate prefill chunks."""
        n_active = self.cfg.n_active_params()
        flops = 2.0 * n_active * n_tokens
        bytes_ = 2.0 * n_active + 2e5 * n_tokens   # weights + kv traffic
        demand = {r: 0.0 for r in RESOURCE_AXES}
        demand.update(mxu=flops, vpu=flops / 50, issue=flops / 256,
                      hbm=bytes_, l2=bytes_)
        return KernelProfile(name, demand=demand)

    def _pick_chunk(self, seq: Sequence, n_active_decodes: int) -> int:
        """Largest chunk whose colocation keeps predicted decode TBT within
        the SLO (paper §5.1 estimator-in-the-loop). Every halving candidate
        down to and INCLUDING the floor chunk is one `Scenario` (victim =
        the decode batch, background = the chunk), priced in a single
        batched solve: predicted TBT = the decode step inflated by the
        chunk's interference, plus the chunk itself serialized on the core
        it is interleaved with.  When no candidate passes, the fallback is
        estimator-backed too: the priced candidate with the lowest
        predicted TBT.

        Degraded mode (``set_degraded``, driven by the fleet layer when
        this device is oversubscribed): skip the largest-passing search
        and always take the minimum-predicted-TBT candidate — the
        interference budget belongs to the migrated/SLO work, not to
        prefill throughput."""
        remaining = seq.prompt_len - seq.pos
        if self.ecfg.mode == "serial":
            return remaining
        if self.ecfg.mode == "fixed_chunk":
            return min(self.ecfg.prefill_chunk, remaining)
        if n_active_decodes == 0:
            boost = 1 if self.degraded else 4
            return min(self.ecfg.prefill_chunk * boost, remaining)
        chunk = min(self.ecfg.prefill_chunk, remaining)
        cands = []
        while chunk > _MIN_CHUNK:
            cands.append(chunk)
            chunk //= 2
        cands.append(max(chunk, _MIN_CHUNK))   # the floor chunk is priced too
        decode = self._phase_profile("decode", max(n_active_decodes, 1))
        chunks = [self._phase_profile(f"prefill{c}", c) for c in cands]
        br = solve_scenarios([Scenario((decode,), (ch,)) for ch in chunks],
                             self.dev)
        tbt_iso = decode.isolated_time(self.dev)
        t_chunk = np.asarray([ch.isolated_time(self.dev) for ch in chunks])
        tbt_pred = tbt_iso * br.slowdowns[:, 0] + t_chunk
        if self.degraded:
            return cands[int(np.argmin(tbt_pred))]
        ok = tbt_pred <= max(self.ecfg.tbt_slo_ms / 1e3, tbt_iso * 1.5)
        passing = np.flatnonzero(ok)
        if passing.size:
            return cands[passing[0]]
        # nothing keeps TBT within SLO: degrade to the estimator-backed
        # minimum — the priced candidate with the lowest predicted TBT
        # (the old fallback returned an unpriced cands[-1] // 2)
        return cands[int(np.argmin(tbt_pred))]

    # ----------------------------- loop --------------------------- #
    def step(self) -> bool:
        """One scheduler iteration. Returns False when idle."""
        now = time.perf_counter
        # 1) admit waiting sequences into free slots
        while self.waiting and self.alloc.can_admit(self.waiting[0]):
            seq = self.waiting.pop(0)
            self.alloc.admit(seq)
            self.events.append(StepEvent("admit", now(),
                                         {"seq": seq.seq_id, "slot": seq.slot}))
        active = list(self.alloc.active.values())
        prefilling = [s for s in active if s.pos < s.prompt_len]
        decoding = [s for s in active if s.pos >= s.prompt_len and not s.done]
        if not active:
            return False

        # 2) one prefill chunk for the oldest prefilling sequence
        if prefilling:
            seq = prefilling[0]
            chunk = self._pick_chunk(seq, len(decoding))
            tok = np.asarray(seq.tokens[seq.pos:seq.pos + chunk],
                             np.int32)[None, :]
            logits, self.cache = self._extend(
                self.params, jnp.asarray(tok), self.cache,
                seq.slot, seq.pos)
            self.events.append(StepEvent(
                "prefill_chunk", now(),
                {"seq": seq.seq_id, "chunk": int(tok.shape[1]),
                 "colocated_decodes": len(decoding)}))
            seq.pos += tok.shape[1]
            if seq.pos >= seq.prompt_len:
                nxt = self._sample(np.asarray(logits)[0, -1])
                seq.tokens.append(nxt)
                seq.first_token_time = now()
                seq.pos += 1

        # 3) one decode step for the whole decode batch
        if decoding:
            B = self.ecfg.max_slots
            tokens = np.zeros((B, 1), np.int32)
            pos = np.full((B,), self.ecfg.max_len, np.int32)   # trash slot
            for s in decoding:
                tokens[s.slot, 0] = s.tokens[-1]
                pos[s.slot] = s.pos - 1   # position of the token being fed
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(pos))
            logits = np.asarray(logits)
            self.events.append(StepEvent("decode", now(),
                                         {"batch": len(decoding)}))
            for s in decoding:
                nxt = self._sample(logits[s.slot, 0])
                s.tokens.append(nxt)
                s.pos += 1
                if s.pos - s.prompt_len >= s.max_new:
                    s.done = True
                    self._finish(s)
        return True

    def _sample(self, logits: np.ndarray) -> int:
        if self.ecfg.temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / self.ecfg.temperature)
        p /= p.sum()
        return int(np.random.default_rng(self.ecfg.seed).choice(len(p), p=p))

    def _finish(self, seq: Sequence):
        self.metrics[seq.seq_id] = {
            "prompt_len": seq.prompt_len,
            "new_tokens": len(seq.tokens) - seq.prompt_len,
            "ttft_s": (seq.first_token_time or 0) - seq.arrival,
            "output": seq.tokens[seq.prompt_len:],
        }
        self.alloc.release(seq.seq_id)
        self.events.append(StepEvent("finish", time.perf_counter(),
                                     {"seq": seq.seq_id}))

    def run_until_done(self, max_steps: int = 10_000) -> Dict[int, dict]:
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                break
        return self.metrics
