from repro.serve.engine import Engine, EngineConfig  # noqa: F401
from repro.serve.kvcache import Sequence, SlotAllocator  # noqa: F401
