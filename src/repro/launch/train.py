"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \\
      --steps 200 --batch 8 --seq 256 --tiny --ckpt /tmp/ck

On real hardware: builds the production mesh, applies the fsdp_tp recipe
and runs the same Trainer; on this CPU container use --tiny for the
reduced config (examples/train_tiny_lm.py drives a ~100M model).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.configs.registry import get_config, tiny_config
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import LOCAL_CTX, ParallelContext, build_model
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_config(cfg)
    over = {"attn_impl": "flashref"}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    cfg = cfg.with_overrides(**over)

    model = build_model(cfg)
    run = RunConfig(num_microbatches=args.microbatches,
                    optimizer=args.optimizer)
    tcfg = TrainerConfig(total_steps=args.steps, optimizer=args.optimizer,
                         lr=args.lr, checkpoint_dir=args.ckpt,
                         checkpoint_every=args.ckpt_every)
    trainer = Trainer(model, run, tcfg, ctx=LOCAL_CTX)

    data = Prefetcher(SyntheticLM(cfg, DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size, seed=args.seed)))
    params, _, history = trainer.fit(data, jax.random.PRNGKey(args.seed))
    data.close()
    losses = [h[1] for h in history]
    print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"params {sum(np.prod(p.shape) for p in jax.tree.leaves(params)):,}")
    return losses


if __name__ == "__main__":
    main()
