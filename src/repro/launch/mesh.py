"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
