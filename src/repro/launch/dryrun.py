import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# post-SPMD pre-backend HLO dump: the CPU backend upcasts bf16->f32 and
# refuses bf16 collectives, so executed-bytes accounting reads the
# after_spmd-partitioning snapshot where dtypes are still faithful to TPU.
_XDUMP = "/tmp/repro_xdump"
os.environ["XLA_FLAGS"] += (
    f" --xla_dump_to={_XDUMP} --xla_dump_hlo_pass_re=spmd-partitioning")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct stand-ins (no allocation), dump memory/cost analysis and
HLO-derived collective traffic to results/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse
import glob
import json
import shutil
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, SHAPES, supports_shape
from repro.configs.registry import get_config, list_archs, valid_cells
from repro.core import hlo as hlo_mod
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.moe import ParallelContext
from repro.parallel import sharding as shd
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ----------------------------------------------------------------- #
#  Per-cell run configuration (hillclimb overrides live here)       #
# ----------------------------------------------------------------- #
def default_run(cfg, shape, n_data_shards: int = 16) -> RunConfig:
    from repro.launch.perf_overrides import PERF_OVERRIDES
    key = (cfg.name, shape.name)
    if key in PERF_OVERRIDES:
        return PERF_OVERRIDES[key]
    if shape.kind == "train":
        big = cfg.n_params() > 30e9
        mb = 16 if big else 4
        mb = max(1, min(mb, shape.global_batch // max(n_data_shards, 1)))
        return RunConfig(
            num_microbatches=mb,
            optimizer="adafactor" if cfg.n_params() > 100e9 else "adamw",
        )
    return RunConfig(num_microbatches=1)


def input_specs(cfg, shape, model):
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.family == "audio":
            batch["frames"] = f((B, S, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = f((B, S), jnp.int32)
        batch["labels"] = f((B, S), jnp.int32)
        if cfg.family == "vlm":
            batch["vision"] = f((B, cfg.n_vision_tokens, cfg.d_vision),
                                jnp.bfloat16)
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a full cache
    tokens = f((B, 1), jnp.int32)
    cache = model.init_cache(B, S, abstract=True)
    pos = f((), jnp.int32)
    return {"tokens": tokens, "cache": cache, "pos": pos}


def _tree_bytes(tree) -> int:
    return sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def _read_spmd_dump() -> str:
    """Newest after_spmd-partitioning dump (cleared per compile)."""
    files = glob.glob(f"{_XDUMP}/*after_spmd-partitioning*.txt")
    if not files:
        return ""
    newest = max(files, key=os.path.getmtime)
    return Path(newest).read_text()


def _clear_spmd_dump():
    shutil.rmtree(_XDUMP, ignore_errors=True)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             run: RunConfig | None = None, save: bool = True,
             mesh=None, tag: str = "", keep_dump: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True}
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    data_axes = shd.data_axes_of(mesh)
    n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    run = run or default_run(cfg, shape, n_data)
    cfg = cfg.with_overrides(attn_impl="flashref",
                             remat_policy=run.remat_policy or cfg.remat_policy,
                             layer_group=run.layer_group or cfg.layer_group)
    recipe = run.sharding_recipe
    if recipe == "auto":
        recipe = shd.pick_recipe(cfg, shape)
    ctx = ParallelContext(mesh, data_axes, "model",
                          feature_shard_decode=(recipe == "tp2d_serve"))
    model = build_model(cfg)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = shd.param_specs(cfg, recipe, mesh, params_shape)
    psh = shd.named(mesh, pspecs)
    ins = input_specs(cfg, shape, model)

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt = get_optimizer(run.optimizer)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        ospecs = shd.param_specs(cfg, recipe, mesh, opt_shape)
        osh = shd.named(mesh, ospecs)
        bspecs = shd.sanitize_tree(
            {k: shd.batch_specs(cfg, recipe, mesh, "train").get(
                k, jax.sharding.PartitionSpec(data_axes, None))
             for k in ins}, ins, mesh)
        bsh = shd.named(mesh, bspecs)
        step = make_train_step(model, opt, run, ctx)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None))
        lowered = jitted.lower(params_shape, opt_shape, ins)
    elif shape.kind == "prefill":
        if cfg.is_encoder:
            def step(params, batch):
                return model.forward(params, batch, ctx)[0]
        else:
            def step(params, batch):
                return model.prefill(params, batch, shape.seq_len, ctx)
        bspecs = shd.sanitize_tree(
            {k: v for k, v in shd.batch_specs(cfg, recipe, mesh,
                                              "prefill").items() if k in ins},
            ins, mesh)
        bsh = shd.named(mesh, bspecs)
        out_sh = None
        if not cfg.is_encoder:
            # the produced KV cache must leave the step SHARDED (batch over
            # data, sequence over model) — otherwise XLA replicates it
            out_shape = jax.eval_shape(step, params_shape, ins)
            ospec = (None, shd.named(mesh, shd.cache_specs(
                cfg, recipe, mesh, out_shape[1])))
            out_sh = ospec
        jitted = jax.jit(step, in_shardings=(psh, bsh), out_shardings=out_sh)
        lowered = jitted.lower(params_shape, ins)
    else:  # decode
        def step(params, tokens, cache, pos):
            return model.decode_step(params, tokens, cache, pos, ctx)

        tok_spec = shd.sanitize(jax.sharding.PartitionSpec(data_axes),
                                ins["tokens"].shape, mesh)
        cspecs = shd.cache_specs(cfg, recipe, mesh, ins["cache"])
        csh = shd.named(mesh, cspecs)
        # donate the cache: the serving engine updates it in place, so the
        # dry-run memory analysis must reflect input/output aliasing
        jitted = jax.jit(step, in_shardings=(
            psh, shd.named(mesh, tok_spec), csh, None),
            out_shardings=(None, csh), donate_argnums=(2,))
        lowered = jitted.lower(params_shape, ins["tokens"], ins["cache"],
                               ins["pos"])
    t_lower = time.perf_counter() - t0

    _clear_spmd_dump()
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    spmd_text = _read_spmd_dump()
    final_text = compiled.as_text()
    stats = hlo_mod.analyze(spmd_text if spmd_text else final_text)
    final_stats = hlo_mod.analyze(final_text)
    if not keep_dump:
        _clear_spmd_dump()

    result = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": dict(mesh.shape), "n_chips": n_chips,
        "recipe": recipe, "multi_pod": multi_pod, "tag": tag,
        "run": {"num_microbatches": run.num_microbatches,
                "optimizer": run.optimizer,
                "remat": run.remat_policy or cfg.remat_policy,
                "recipe": recipe},
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "cost": {"flops": float(cost.get("flops", -1)),
                 "bytes_accessed": float(cost.get("bytes accessed", -1)),
                 "transcendentals": float(cost.get("transcendentals", -1))},
        # executed-counts (while bodies multiplied by trip count) per device
        # primary: after_spmd HLO (bf16-faithful, fusion-optimistic bytes);
        # boundary: final backend HLO (fusion-boundary bytes, f32-upcast)
        "hlo_exec": {"mxu_flops": stats.mxu_flops,
                     "vpu_flops": stats.vpu_flops,
                     "transcendentals": stats.transcendentals,
                     "hbm_bytes": stats.hbm_bytes,
                     "hbm_bytes_boundary": final_stats.hbm_bytes,
                     "source": "after_spmd" if spmd_text else "final"},
        "collectives": {"bytes_by_kind": stats.coll_bytes_by_kind,
                        "count_by_kind": stats.coll_count_by_kind,
                        "total_bytes": stats.collective_bytes},
        "hlo_size_chars": len(final_text),
    }
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        fname = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
        fname += (f"__{tag}" if tag else "") + ".json"
        (RESULTS / fname).write_text(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = (valid_cells() if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    ok = fail = 0
    for arch, shape in cells:
        for mp in meshes:
            fname = (RESULTS / f"{arch}__{shape}__"
                     f"{'pod2' if mp else 'pod1'}"
                     f"{'__' + args.tag if args.tag else ''}.json")
            if args.skip_done and fname.exists():
                print(f"SKIP {arch} {shape} mp={mp}")
                continue
            try:
                t0 = time.perf_counter()
                r = run_cell(arch, shape, multi_pod=mp, tag=args.tag)
                dt = time.perf_counter() - t0
                if r.get("skipped"):
                    continue
                print(f"OK   {arch:28s} {shape:12s} mp={int(mp)} "
                      f"compile={r['compile_s']:6.1f}s total={dt:6.1f}s "
                      f"flops/dev={r['cost']['flops']:.3g} "
                      f"coll={r['collectives']['total_bytes']:.3g}B")
                ok += 1
            except Exception as e:
                fail += 1
                print(f"FAIL {arch:28s} {shape:12s} mp={int(mp)}: {e}")
                traceback.print_exc()
    print(f"\ndry-run: {ok} ok, {fail} failed")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
