"""Interference-profile driver: build per-phase resource profiles of an
architecture from its dry-run artifacts and print its sensitivity
fingerprint + best colocation partners (the paper's methodology applied
to the framework's own workloads).

  PYTHONPATH=src python -m repro.launch.profile --arch llama3-405b
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core import TPU_V5E, ColocationScheduler, sensitivity_batch
from repro.core.profile import WorkloadProfile, from_dryrun_json

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def load_profiles(arch: str = None, mesh_tag: str = "pod1"):
    profs = []
    for f in sorted(RESULTS.glob(f"*__{mesh_tag}.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            continue
        if arch and rec["arch"] != arch:
            continue
        profs.append(from_dryrun_json(rec))
    return profs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--plan", action="store_true",
                    help="run the colocation scheduler over all phases")
    ap.add_argument("--group-size", type=int, default=2,
                    help="max workloads per device (k-way placements)")
    args = ap.parse_args(argv)

    profs = load_profiles(args.arch)
    if not profs:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
        return
    print(f"{'phase':44s} {'bottleneck':11s} sensitivity fingerprint "
          f"(slowdown @ 90% stressor)")
    # all phases' fingerprints in one batched estimator solve
    for p, rep in zip(profs, sensitivity_batch(profs, TPU_V5E)):
        fp = " ".join(f"{a}:{rep.scores[a]:.2f}" for a in rep.ranked()[:4])
        print(f"{p.name:44s} {p.bottleneck(TPU_V5E):11s} {fp}")

    if args.plan:
        sched = ColocationScheduler(TPU_V5E, max_group_size=args.group_size)
        for p in profs:
            sched.submit(WorkloadProfile(p.name, (p,), slo_slowdown=1.3))
        plan = sched.plan()
        print(f"\ncolocation plan (SLO 1.3x, k<={args.group_size}):")
        for pl in plan.placements:
            print("  ", pl)
        print("   solo:", plan.solo)


if __name__ == "__main__":
    main()
