import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
_XDUMP = "/tmp/repro_xdump"
os.environ["XLA_FLAGS"] += (
    f" --xla_dump_to={_XDUMP} --xla_dump_hlo_pass_re=spmd-partitioning")

"""Perf-iteration profiler: lower one cell and print the top HBM / FLOPs /
collective contributors from the executed-HLO accounting (the dry-run
analogue of `ncu --print-summary`). Drives the §Perf hypothesis loop.

  PYTHONPATH=src python -m repro.launch.inspect_cell --arch falcon-mamba-7b \\
      --shape train_4k [--microbatches 8] [--recipe fsdp_tp]
"""

import argparse
import re
from collections import defaultdict

from repro.core import hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--recipe", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--topk", type=int, default=12)
    args = ap.parse_args()

    from repro.configs.base import RunConfig, SHAPES
    from repro.configs.registry import get_config
    from repro.launch import dryrun as dr

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    run = dr.default_run(cfg, shape)
    kw = {}
    if args.microbatches:
        kw["num_microbatches"] = args.microbatches
    if args.recipe:
        kw["sharding_recipe"] = args.recipe
    if args.remat:
        kw["remat_policy"] = args.remat
    if args.optimizer:
        kw["optimizer"] = args.optimizer
    if kw:
        import dataclasses
        run = dataclasses.replace(run, **kw)

    # run the cell but keep the spmd dump for deep analysis
    dr._clear_spmd_dump()
    rec = dr.run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                      run=run, save=False)
    print(f"\n=== {args.arch} {args.shape} recipe={rec['recipe']} "
          f"mb={run.num_microbatches} remat={run.remat_policy} ===")
    h = rec["hlo_exec"]
    dev_tf, hbm_bw, ici = 197e12, 819e9, 50e9 * 1.5
    print(f"compute {h['mxu_flops'] / dev_tf * 1e3:9.1f} ms   "
          f"memory {h['hbm_bytes'] / hbm_bw * 1e3:9.1f} ms   "
          f"collective {rec['collectives']['total_bytes'] / ici * 1e3:9.1f} ms")
    mem = rec["memory"]
    print(f"HBM/chip: args {mem['argument_bytes'] / 1e9:.2f} GB  "
          f"temp {mem['temp_bytes'] / 1e9:.2f} GB "
          f"(CPU-f32-inflated; TPU-bf16 ~ /1.7)")

    # deep per-op analysis needs the dump from the LAST compile; run_cell
    # clears it, so re-lower once more without clearing:
    import json
    # Re-run with dump preserved
    dr._clear_spmd_dump()
    rec = dr.run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                      run=run, save=False, keep_dump=True)
    text = dr._read_spmd_dump()
    mod = hlo.parse_module(text)

    def meta(i):
        m = re.search(r'op_name="([^"]*)"', i.attrs or "")
        return (m.group(1)[-70:] if m else i.opcode)

    hbm_by, flop_by, coll_by = (defaultdict(float) for _ in range(3))
    for m, cname, i in mod.executed():
        base = i.opcode.replace("-start", "")
        key = f"{i.opcode[:14]:14s} {meta(i)}"
        if base in hlo.COLLECTIVES and not i.opcode.endswith("-done"):
            ob = sum(mod.table[o].result_bytes for o in i.operands
                     if o in mod.table)
            coll_by[key] += m * hlo._traffic(base, ob, i.result_bytes)
        if i.opcode in ("dot", "convolution"):
            flop_by[key] += m * hlo._dot_flops(i, mod.table)
        if cname in mod.fusion_bodies or i.opcode in hlo._NO_TRAFFIC:
            continue
        if i.opcode in ("dynamic-slice", "slice", "gather"):
            hbm_by[key] += m * 2 * i.result_bytes
        elif i.opcode in ("dynamic-update-slice", "scatter"):
            upd = (mod.table[i.operands[1]].result_bytes
                   if len(i.operands) > 1 and i.operands[1] in mod.table
                   else i.result_bytes)
            hbm_by[key] += m * 2 * upd
        elif i.opcode in ("dot", "convolution", "reduce", "sort"):
            ob = sum(mod.table[o].result_bytes for o in i.operands
                     if o in mod.table)
            hbm_by[key] += m * (ob + i.result_bytes)

    for title, d, unit in (("HBM bytes", hbm_by, 1e9),
                           ("MXU flops", flop_by, 1e12),
                           ("collective bytes", coll_by, 1e9)):
        tot = sum(d.values())
        print(f"\n--- top {title} (total {tot / unit:.2f} "
              f"{'GB' if unit == 1e9 else 'TF'}) ---")
        for k, v in sorted(d.items(), key=lambda kv: -kv[1])[:args.topk]:
            print(f"{v / unit:10.3f} ({v / max(tot, 1e-9) * 100:4.1f}%)  {k}")


if __name__ == "__main__":
    main()
