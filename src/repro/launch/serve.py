"""Serving driver: continuous batching with interference-aware chunked
prefill.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --tiny \\
      --requests 8 --mode interference_aware
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.registry import get_config, tiny_config
from repro.serve import Engine, EngineConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--mode", default="interference_aware",
                    choices=["serial", "fixed_chunk", "interference_aware"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = tiny_config(cfg)
    rng = np.random.default_rng(args.seed)
    eng = Engine(cfg, ecfg=EngineConfig(
        max_slots=args.slots, max_len=args.max_len, mode=args.mode))
    for i in range(args.requests):
        plen = int(rng.integers(8, args.max_len - args.max_new - 1))
        prompt = rng.integers(1, cfg.vocab_size, size=plen).tolist()
        eng.submit(prompt, max_new=args.max_new)
    t0 = time.perf_counter()
    metrics = eng.run_until_done()
    dt = time.perf_counter() - t0
    toks = sum(m["new_tokens"] for m in metrics.values())
    print(f"mode={args.mode}: {len(metrics)} requests, {toks} tokens "
          f"in {dt:.2f}s")
    chunks = [e.detail["chunk"] for e in eng.events
              if e.kind == "prefill_chunk"]
    print(f"prefill chunks: n={len(chunks)} sizes={chunks}")
    return metrics


if __name__ == "__main__":
    main()
