"""Per-(arch, shape) RunConfig overrides produced by the §Perf hillclimb.

Provenance for each entry is the iteration log in EXPERIMENTS.md §Perf
(hypothesis -> change -> before -> after -> confirmed/refuted).
"""
from repro.configs.base import RunConfig

PERF_OVERRIDES: dict = {
    # A-series: falcon-mamba train — after the sequential-scan rewrite the
    # activation floor allows mb=8, which fits HBM (13GB/chip)
    ("falcon-mamba-7b", "train_4k"): RunConfig(
        num_microbatches=8, optimizer="adamw"),
    # B-series: llama3-405b train — mb=8 minimizes the per-microbatch
    # weight-gather + grad-reduce volume (coll 90s -> 68s); mb=16 is the
    # HBM-conservative setting (66GB vs 117GB CPU-inflated temp).
    ("llama3-405b", "train_4k"): RunConfig(
        num_microbatches=16, optimizer="adafactor"),
    ("zamba2-1.2b", "train_4k"): RunConfig(num_microbatches=8),
}
