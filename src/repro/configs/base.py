"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a frozen ``ModelConfig``.  The
same dataclass drives model construction (``repro.models.model.build_model``),
sharding-recipe selection (``repro.parallel.sharding``), the dry-run
(``repro.launch.dryrun``) and the interference profiler (``repro.core``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0        # hidden dim of each expert MLP
    n_shared_experts: int = 0   # always-on experts (moonlight-style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    variant: str = "mamba1"     # "mamba1" | "mamba2"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # mamba2 only:
    n_heads: int = 0            # SSD heads; head_dim = d_inner // n_heads
    chunk_size: int = 128


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    # attention pattern: "global" | "local_global" (gemma3) | "bidirectional"
    pattern: str = "global"
    local_window: int = 1024
    local_ratio: int = 5        # local:global = local_ratio : 1
    softcap: float = 0.0        # logit softcapping (gemma2-style), 0 = off


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: AttentionConfig = field(default_factory=AttentionConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    act: str = "silu"           # "silu" | "gelu" | "geglu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False   # scale embeddings by sqrt(d_model) (gemma)
    is_encoder: bool = False    # encoder-only (hubert): bidirectional, no KV cache
    # vlm: every `cross_attn_every`-th layer is a cross-attention layer
    cross_attn_every: int = 0
    n_vision_tokens: int = 0    # stub frontend: precomputed patch embeddings
    d_vision: int = 0
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    hybrid_attn_every: int = 0
    # numerics
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"
    # perf knobs (hillclimbable; can be overridden per shape via RunConfig)
    # "full" recomputes the layer in bwd (flash-attention-compatible: never
    # saves S^2 score tensors); "minimal" saves dot outputs; "none" = no remat
    remat_policy: str = "full"
    layer_group: int = 1    # checkpoint every g layers (B2)
    scan_layers: bool = True
    attn_impl: str = "auto"     # "auto" | "reference" | "flashref" | "pallas"
    source: str = ""            # provenance note

    # ------------------------------------------------------------------ #
    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def n_heads(self) -> int:
        return self.attn.n_heads

    @property
    def head_dim(self) -> int:
        return self.attn.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    def n_params(self) -> int:
        """Analytic total parameter count (matches init within ~1%)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        a = self.attn
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            qkvo = d * a.n_heads * a.head_dim * 2 + d * a.n_kv_heads * a.head_dim * 2
            n_mats = 3 if self.act in ("silu", "geglu") else 2
            if self.family == "moe":
                m = self.moe
                mlp = m.n_experts * (n_mats * d * m.d_ff_expert) + d * m.n_experts
                mlp += m.n_shared_experts * (n_mats * d * m.d_ff_expert)
            else:
                mlp = n_mats * d * self.d_ff
            per_layer = qkvo + mlp + 2 * d
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = L // self.cross_attn_every
                cross = (d * a.n_heads * a.head_dim * 2
                         + self.d_vision * a.n_kv_heads * a.head_dim * 2 + d)
                emb += n_cross * cross
        elif self.family == "ssm":
            di, s = self.d_inner, self.ssm.d_state
            per_layer = (d * di * 2          # in_proj (x, z)
                         + di * self.ssm.d_conv
                         + di * s * 2        # B,C proj (via x_proj) approx
                         + di * (di // 16)   # dt_proj approx
                         + di * s            # A
                         + di * d            # out_proj
                         + 2 * d)
        elif self.family == "hybrid":
            di, s = self.d_inner, self.ssm.d_state
            per_layer = (d * di * 2 + di * self.ssm.d_conv + di * s * 2
                         + di + di * d + 2 * d)
            if self.hybrid_attn_every:
                qkvo = d * a.n_heads * a.head_dim * 2 + d * a.n_kv_heads * a.head_dim * 2
                emb += qkvo + 3 * d * self.d_ff + 2 * d   # one SHARED block
        return emb + L * per_layer

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        m = self.moe
        n_mats = 3 if self.act in ("silu", "geglu") else 2
        dense_like = self.n_params() - L * m.n_experts * (n_mats * d * m.d_ff_expert)
        return dense_like + L * (m.top_k) * (n_mats * d * m.d_ff_expert)


# ---------------------------------------------------------------------- #
#  Input shapes (assigned shape set)                                      #
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class RunConfig:
    """Per-(arch, shape) execution knobs — the hillclimb surface."""
    sharding_recipe: str = "auto"    # see parallel/sharding.py
    num_microbatches: int = 1
    remat_policy: Optional[str] = None   # override ModelConfig.remat_policy
    optimizer: str = "adamw"             # "adamw" | "adafactor"
    use_grad_compression: bool = False
    scan_unroll: int = 1
    layer_group: int = 0                 # 0 = model default
    attn_chunk: int = 1024               # flashref KV-chunk size
    decode_kv_seq_shards: int = 0        # 0 = recipe default


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Applicability matrix (documented in DESIGN.md §4)."""
    if shape.kind == "decode" and cfg.is_encoder:
        return False
    if shape.name == "long_500k":
        subquadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.attn.pattern == "local_global"
        )
        return subquadratic and not cfg.is_encoder
    return True
