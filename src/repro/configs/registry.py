"""Architecture registry: ``get_config(name)`` / ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, supports_shape

from repro.configs import (
    hubert_xlarge, falcon_mamba_7b, llama32_vision_90b, llama3_405b,
    gemma_2b, qwen3_1p7b, gemma3_4b, phi35_moe, moonshot_v1_16b,
    zamba2_1p2b, gemma3_1b, llama31_8b,
)

ASSIGNED: List[ModelConfig] = [
    hubert_xlarge.CONFIG,
    falcon_mamba_7b.CONFIG,
    llama32_vision_90b.CONFIG,
    llama3_405b.CONFIG,
    gemma_2b.CONFIG,
    qwen3_1p7b.CONFIG,
    gemma3_4b.CONFIG,
    phi35_moe.CONFIG,
    moonshot_v1_16b.CONFIG,
    zamba2_1p2b.CONFIG,
]

PAPER_WORKLOADS: List[ModelConfig] = [gemma3_1b.CONFIG, llama31_8b.CONFIG]

_REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in ASSIGNED + PAPER_WORKLOADS}

# short aliases
_ALIASES = {
    "hubert": "hubert-xlarge",
    "falcon-mamba": "falcon-mamba-7b",
    "llama-vision": "llama-3.2-vision-90b",
    "llama-405b": "llama3-405b",
    "qwen3": "qwen3-1.7b",
    "phi-moe": "phi3.5-moe-42b-a6.6b",
    "moonshot": "moonshot-v1-16b-a3b",
    "zamba2": "zamba2-1.2b",
}


def get_config(name: str) -> ModelConfig:
    key = _ALIASES.get(name, name)
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_archs(assigned_only: bool = False) -> List[str]:
    return [c.name for c in (ASSIGNED if assigned_only else ASSIGNED + PAPER_WORKLOADS)]


def valid_cells() -> List[tuple]:
    """All (arch_name, shape_name) cells per the applicability matrix."""
    cells = []
    for cfg in ASSIGNED:
        for sname, shape in SHAPES.items():
            if supports_shape(cfg, shape):
                cells.append((cfg.name, sname))
    return cells


def tiny_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(2, (cfg.hybrid_attn_every or 2)),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
    )
    attn = cfg.attn
    if attn.n_heads:
        ratio = max(1, attn.n_heads // max(attn.n_kv_heads, 1))
        kw["attn"] = attn.__class__(
            n_heads=4, n_kv_heads=max(1, 4 // ratio) if ratio > 1 else 4,
            head_dim=16, qk_norm=attn.qk_norm, rope_theta=attn.rope_theta,
            pattern=attn.pattern, local_window=8, local_ratio=attn.local_ratio,
        )
    if cfg.family == "moe":
        # capacity_factor 8: no token drops in tiny tests (parity checks)
        kw["moe"] = cfg.moe.__class__(
            n_experts=4, top_k=2, d_ff_expert=64,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            capacity_factor=8.0)
    if cfg.family in ("ssm", "hybrid"):
        kw["ssm"] = cfg.ssm.__class__(
            variant=cfg.ssm.variant, d_state=8, d_conv=4, expand=2,
            n_heads=4 if cfg.ssm.variant == "mamba2" else 0, chunk_size=16)
    if cfg.family == "vlm":
        kw["cross_attn_every"] = 5
        kw["n_layers"] = 10
        kw["n_vision_tokens"] = 16
        kw["d_vision"] = 32
    if cfg.family == "hybrid":
        kw["n_layers"] = 2 * cfg.hybrid_attn_every if cfg.hybrid_attn_every else 4
        kw["n_layers"] = min(kw["n_layers"], 12)
    return cfg.with_overrides(**kw)
