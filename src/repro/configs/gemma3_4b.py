"""Gemma3-4B — dense decoder with 5:1 local:global attention, 128k context.

[hf:google/gemma-3-*-pt; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144, head_dim=256, sliding window 1024 on local layers.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    d_ff=10240,
    vocab_size=262144,
    attn=AttentionConfig(n_heads=8, n_kv_heads=4, head_dim=256,
                         pattern="local_global", local_window=1024,
                         local_ratio=5, rope_theta=1_000_000.0),
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
