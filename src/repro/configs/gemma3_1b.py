"""Gemma3-1B — paper workload (§4.4.2 of the paper uses Gemma3-1B-IT decode).

[hf:google/gemma-3-1b-pt] 26L d_model=1152 4H (kv=1) d_ff=6912 vocab=262144,
head_dim=256, 5:1 local:global with window 512.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    d_ff=6912,
    vocab_size=262144,
    attn=AttentionConfig(n_heads=4, n_kv_heads=1, head_dim=256,
                         pattern="local_global", local_window=512,
                         local_ratio=5, rope_theta=1_000_000.0),
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; paper workload",
)
