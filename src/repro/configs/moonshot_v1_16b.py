"""Moonshot/Moonlight-16B-A3B — fine-grained MoE, 64 experts top-6 (+2 shared).

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (GQA kv=16)
expert d_ff=1408 vocab=163840.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    d_ff=1408,
    vocab_size=163840,
    attn=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                         rope_theta=50_000.0),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2),
    tie_embeddings=True,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
