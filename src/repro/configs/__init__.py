from repro.configs.base import (  # noqa: F401
    AttentionConfig, ModelConfig, MoEConfig, RunConfig, SSMConfig,
    ShapeConfig, SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    supports_shape,
)
