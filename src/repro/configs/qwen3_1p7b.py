"""Qwen3-1.7B — dense decoder with QK-norm and GQA.

[hf:Qwen/Qwen3-8B family; hf] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    d_ff=6144,
    vocab_size=151936,
    attn=AttentionConfig(n_heads=16, n_kv_heads=8, head_dim=128,
                         qk_norm=True, rope_theta=1_000_000.0),
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf",
)
