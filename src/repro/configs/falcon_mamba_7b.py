"""Falcon-Mamba-7B — attention-free Mamba-1 SSM LM.

[arXiv:2410.05355] 64L d_model=4096 vocab=65024 ssm_state=16, expand=2
(d_inner=8192), conv4.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    d_ff=0,
    vocab_size=65024,
    ssm=SSMConfig(variant="mamba1", d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
    source="arXiv:2410.05355; unverified",
)
