"""Llama-3-405B — dense GQA decoder. [arXiv:2407.21783; unverified]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    d_ff=53248,
    vocab_size=128256,
    attn=AttentionConfig(n_heads=128, n_kv_heads=8, head_dim=128,
                         rope_theta=500_000.0),
    tie_embeddings=False,
    source="arXiv:2407.21783; unverified",
)
