"""Llama-3.1-8B — paper workload (§4.2/§4.3 decode TBT experiments).

[hf:meta-llama/Llama-3.1-8B-Instruct] 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    attn=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                         rope_theta=500_000.0),
    tie_embeddings=False,
    source="hf:meta-llama/Llama-3.1-8B-Instruct; paper workload",
)
