"""Llama-3.2-Vision-90B — decoder LM backbone with interleaved cross-attn
image layers. [hf:meta-llama/Llama-3.2-11B-Vision scaled; unverified]

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. Every 5th layer is
a cross-attention layer over vision tokens (20 cross layers). The vision
encoder is a STUB: precomputed patch embeddings (B, n_vision_tokens, d_vision).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    d_ff=28672,
    vocab_size=128256,
    attn=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                         rope_theta=500_000.0),
    cross_attn_every=5,
    n_vision_tokens=4096,
    d_vision=1280,
    tie_embeddings=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
