"""HuBERT X-Large — encoder-only audio transformer backbone.

[arXiv:2106.07447] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means
units). The conv waveform frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings of shape (B, T, d_model).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    attn=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=80,
                         pattern="bidirectional", rope_theta=10_000.0),
    act="gelu",
    is_encoder=True,
    tie_embeddings=False,
    source="arXiv:2106.07447; unverified",
)
