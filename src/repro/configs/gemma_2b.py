"""Gemma-2B — dense decoder, GeGLU, MQA (kv=1), head_dim=256.

[arXiv:2403.08295; hf] 18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=256000,
    attn=AttentionConfig(n_heads=8, n_kv_heads=1, head_dim=256,
                         rope_theta=10_000.0),
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2403.08295; hf",
)
