"""Zamba2-1.2B — hybrid: Mamba-2 backbone + one SHARED attention block
applied every 6 SSM layers. [arXiv:2411.15242; hf]

38L d_model=2048 32H (kv=32, head_dim=64) d_ff=8192 vocab=32000 ssm_state=64.
"""
from repro.configs.base import AttentionConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab_size=32000,
    attn=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=64,
                         rope_theta=10_000.0),
    ssm=SSMConfig(variant="mamba2", d_state=64, d_conv=4, expand=2,
                  n_heads=64, chunk_size=128),
    hybrid_attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242; hf",
)
