"""Phi-3.5-MoE (42B total, 6.6B active) — 16 experts, top-2.

[hf:microsoft/Phi-3.5-MoE-instruct; hf] 32L d_model=4096 32H (GQA kv=8)
expert d_ff=6400 vocab=32064.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    attn=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                         rope_theta=10_000.0),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    tie_embeddings=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
)
