"""Shared pure-JAX building blocks: norms, RoPE, MLPs, embeddings.

Parameters are plain nested dicts of ``jnp.ndarray``; initializers take an
explicit PRNG key. Compute dtype is bf16 with f32 for norms/softmax/logits.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------- #
#  RMSNorm                                                               #
# --------------------------------------------------------------------- #
def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def l2norm(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Per-head QK-norm (qwen3-style, scale-free variant)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# --------------------------------------------------------------------- #
#  Rotary position embedding                                             #
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)              # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                              # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
#  MLP (silu / gelu / geglu)                                             #
# --------------------------------------------------------------------- #
def mlp_init(key, d_model: int, d_ff: int, act: str, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d_model, d_ff, dtype),
         "w_down": dense_init(k2, d_ff, d_model, dtype)}
    if act in ("silu", "geglu"):
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if act == "silu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(g) * up
    elif act == "geglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.gelu(g, approximate=True) * up
    elif act == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(f"unknown act {act!r}")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# --------------------------------------------------------------------- #
#  Embedding / unembedding                                               #
# --------------------------------------------------------------------- #
def embed_init(key, vocab: int, d_model: int, tie: bool,
               dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"embedding": (jax.random.normal(k1, (vocab, d_model), jnp.float32)
                       * (1.0 / math.sqrt(d_model))).astype(dtype)}
    if not tie:
        p["unembed"] = dense_init(k2, d_model, vocab, dtype)
    return p


def embed(p: Params, tokens: jnp.ndarray, scale_by_dim: bool = False) -> jnp.ndarray:
    x = jnp.take(p["embedding"], tokens, axis=0)
    if scale_by_dim:
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), x.dtype)
    return x


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Returns f32 logits."""
    if "unembed" in p:
        return jnp.einsum("...d,dv->...v", x, p["unembed"],
                          preferred_element_type=jnp.float32)
    return jnp.einsum("...d,vd->...v", x, p["embedding"],
                      preferred_element_type=jnp.float32)


# --------------------------------------------------------------------- #
#  Loss                                                                  #
# --------------------------------------------------------------------- #
def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token CE. logits (..., V) f32, labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
