"""Decoder/encoder transformer stacks with scan-over-layers.

Supports: dense GQA decoders, MoE decoders, encoder-only (audio), VLM
(grouped scan: N self-attn layers + 1 cross-attn layer per group), and
gemma3-style local:global attention (grouped scan: `ratio` local + 1 global).

All stacks use ``jax.lax.scan`` over stacked layer params so the compiled
HLO contains each distinct layer body exactly once (fast compiles at 126
layers, compact dry-run HLO) and ``jax.checkpoint`` for rematerialization.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (Params, mlp, mlp_init, rmsnorm, rmsnorm_init)
from repro.models.moe import ParallelContext, moe_ffn, moe_init

Cache = Dict[str, Any]


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "minimal":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if policy == "full":
        return jax.checkpoint(fn)
    raise ValueError(f"unknown remat policy {policy!r}")


def _stack_init(fn, key, n: int):
    """vmap an init fn over n split keys -> stacked (n, ...) params."""
    return jax.vmap(fn)(jax.random.split(key, n))


# ===================================================================== #
#  One decoder layer (pre-norm attn + pre-norm FFN/MoE)                  #
# ===================================================================== #
def layer_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln1": rmsnorm_init(cfg.d_model),
         "ln2": rmsnorm_init(cfg.d_model),
         "attn": attn.attn_init(k1, cfg.attn, cfg.d_model, dtype=dtype)}
    if cfg.family == "moe":
        p["moe"] = moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def layer_fwd(lp: Params, cfg: ModelConfig, x: jnp.ndarray, *,
              kind: str, ctx: ParallelContext, impl: str, chunk: int,
              positions: Optional[jnp.ndarray] = None,
              return_kv: bool = False):
    h, kv = attn.self_attention_block(
        lp["attn"], cfg.attn, rmsnorm(lp["ln1"], x, cfg.norm_eps),
        kind=kind, impl=impl, chunk=chunk, positions=positions, ctx=ctx)
    x = x + h
    y = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = moe_ffn(lp["moe"], cfg, y, ctx)
    else:
        f, aux = mlp(lp["mlp"], y, cfg.act), jnp.zeros((), jnp.float32)
    x = x + f
    return x, (kv if return_kv else None), aux


def layer_decode(lp: Params, cfg: ModelConfig, x, ck, cv, pos, *, kind, ctx):
    # tp2d decode: the whole residual stream stays FEATURE-sharded
    # (B, 1, d@data) so every weight (d@data, out@model) contracts against
    # its resident shard; only decode-sized activation psums move (§Perf C2)
    fsd = bool(getattr(ctx, "feature_shard_decode", False)
               and getattr(ctx, "mesh", None) is not None)

    def fshard(u):
        return attn._shard(u, ctx, None, None, ctx.data_axes) if fsd else u

    y1 = fshard(rmsnorm(lp["ln1"], x, cfg.norm_eps))
    h, ck, cv = attn.decode_self_attention(
        lp["attn"], cfg.attn, y1, ck, cv, pos, kind=kind)
    x = x + fshard(h)
    y = fshard(rmsnorm(lp["ln2"], x, cfg.norm_eps))
    if cfg.family == "moe":
        f, _ = moe_ffn(lp["moe"], cfg, y, ctx)
    else:
        f = mlp(lp["mlp"], y, cfg.act)
    return x + fshard(f), ck, cv


# ===================================================================== #
#  Uniform stack (dense, moe, audio encoder)                             #
# ===================================================================== #
def uniform_stack_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    return _stack_init(lambda k: layer_init(k, cfg, dtype), key, cfg.n_layers)


def _kind_for(cfg: ModelConfig) -> str:
    return "bidirectional" if cfg.is_encoder else "causal"


def _group_stack(sp, g: int):
    """(L, ...) stacked params -> (L/g, g, ...) for layer-group remat."""
    def re(p):
        return p.reshape(p.shape[0] // g, g, *p.shape[1:])

    return jax.tree.map(re, sp)


def uniform_stack_fwd(sp: Params, cfg: ModelConfig, x, *, ctx, impl, chunk,
                      remat: str, unroll: int = 1, collect_kv: bool = False):
    """Layer-group remat (§Perf B2): the outer scan checkpoints only every
    ``cfg.layer_group`` layers, dividing the dominant bwd-saved activation
    (the per-layer residual carry) by the group size at no extra
    recompute — each layer is still executed exactly twice (fwd + replay).

    (A Megatron-SP seq-sharded-residual variant was tried and REFUTED:
    GSPMD materializes full-d_ff cotangents, 3x the collective bytes —
    see EXPERIMENTS.md §Perf B1.)
    """
    kind = _kind_for(cfg)

    def body(carry, lp):
        h, aux = carry
        h, kv, a = layer_fwd(lp, cfg, h, kind=kind, ctx=ctx, impl=impl,
                             chunk=chunk, return_kv=collect_kv)
        return (h, aux + a), kv

    g = max(1, getattr(cfg, "layer_group", 1))
    if g > 1 and cfg.n_layers % g == 0 and remat != "none":
        def group_body(carry, gp):
            return jax.lax.scan(body, carry, gp)

        (x, aux), kvs = jax.lax.scan(
            _remat(group_body, remat), (x, jnp.zeros((), jnp.float32)),
            _group_stack(sp, g), unroll=unroll)
        if collect_kv:
            kvs = jax.tree.map(lambda u: u.reshape(-1, *u.shape[2:]), kvs)
    else:
        (x, aux), kvs = jax.lax.scan(_remat(body, remat),
                                     (x, jnp.zeros((), jnp.float32)),
                                     sp, unroll=unroll)
    return x, aux, kvs      # kvs: (k (L,B,S,KVH,D), v (...)) if collect_kv


def uniform_stack_extend(sp: Params, cfg: ModelConfig, x, cache_k, cache_v,
                         pos0, *, ctx):
    """Chunked prefill: run C tokens through the stack, extending caches
    in place (engine path for continuous batching — paper takeaway #1:
    fine-grained scheduling units)."""
    def body(h, inp):
        lp, ck, cv = inp
        out, ck, cv = attn.extend_self_attention(
            lp["attn"], cfg.attn, rmsnorm(lp["ln1"], h, cfg.norm_eps),
            ck, cv, pos0)
        h = h + out
        y = rmsnorm(lp["ln2"], h, cfg.norm_eps)
        if cfg.family == "moe":
            f, _ = moe_ffn(lp["moe"], cfg, y, ctx)
        else:
            f = mlp(lp["mlp"], y, cfg.act)
        return h + f, (ck, cv)

    x, (cache_k, cache_v) = jax.lax.scan(body, x, (sp, cache_k, cache_v))
    return x, cache_k, cache_v


def uniform_stack_decode(sp: Params, cfg: ModelConfig, x, cache_k, cache_v,
                         pos, *, ctx):
    kind = _kind_for(cfg)

    def body(h, inp):
        lp, ck, cv = inp
        h, ck, cv = layer_decode(lp, cfg, h, ck, cv, pos, kind=kind, ctx=ctx)
        return h, (ck, cv)

    x, (cache_k, cache_v) = jax.lax.scan(body, x, (sp, cache_k, cache_v))
    return x, cache_k, cache_v


# ===================================================================== #
#  local:global grouped stack (gemma3)                                   #
# ===================================================================== #
def lg_split(cfg: ModelConfig) -> Tuple[int, int]:
    """Returns (n_groups, n_tail_local). Pattern per group: `ratio` local
    layers then 1 global layer; trailing layers are local."""
    r = cfg.attn.local_ratio
    g = cfg.n_layers // (r + 1)
    return g, cfg.n_layers - g * (r + 1)


def lg_stack_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    g, tail = lg_split(cfg)
    r = cfg.attn.local_ratio
    k1, k2, k3 = jax.random.split(key, 3)
    init1 = lambda k: layer_init(k, cfg, dtype)
    return {
        "locals": jax.vmap(lambda k: _stack_init(init1, k, r))(
            jax.random.split(k1, g)),                      # (g, r, ...)
        "globals": _stack_init(init1, k2, g),              # (g, ...)
        "tail": _stack_init(init1, k3, tail) if tail else None,
    }


def lg_stack_fwd(sp: Params, cfg: ModelConfig, x, *, ctx, impl, chunk,
                 remat: str, unroll: int = 1, collect_kv: bool = False):
    aux0 = jnp.zeros((), jnp.float32)

    def local_body(carry, lp):
        h, aux = carry
        h, kv, a = layer_fwd(lp, cfg, h, kind="local", ctx=ctx, impl=impl,
                             chunk=chunk, return_kv=collect_kv)
        if collect_kv:  # trailing window stored at its RING slots (slot=p%W)
            W = cfg.attn.local_window
            S = kv[0].shape[1]
            if S >= W:
                inv = (jnp.arange(W) - S) % W
                kv = tuple(u[:, -W:][:, inv] for u in kv)
            else:
                kv = tuple(jnp.pad(u, ((0, 0), (0, W - S), (0, 0), (0, 0)))
                           for u in kv)
        return (h, aux + a), kv

    def group_body(carry, gp):
        (h, aux), lkvs = jax.lax.scan(local_body, carry, gp["locals"])
        h, gkv, a = layer_fwd(gp["globals"], cfg, h, kind="causal", ctx=ctx,
                              impl=impl, chunk=chunk, return_kv=collect_kv)
        return (h, aux + a), (lkvs, gkv)

    (x, aux), (local_kvs, global_kvs) = jax.lax.scan(
        _remat(group_body, remat), (x, aux0),
        {"locals": sp["locals"], "globals": sp["globals"]}, unroll=unroll)
    tail_kvs = None
    if sp.get("tail") is not None:
        (x, aux), tail_kvs = jax.lax.scan(_remat(local_body, remat),
                                          (x, aux), sp["tail"])
    return x, aux, (local_kvs, global_kvs, tail_kvs)


def lg_stack_decode(sp: Params, cfg: ModelConfig, x, cache: Cache, pos, *, ctx):
    def local_body(h, inp):
        lp, ck, cv = inp
        h, ck, cv = layer_decode(lp, cfg, h, ck, cv, pos, kind="local", ctx=ctx)
        return h, (ck, cv)

    def group_body(h, inp):
        gp, lck, lcv, gck, gcv = inp
        h, (lck, lcv) = jax.lax.scan(local_body, h, (gp["locals"], lck, lcv))
        h, gck, gcv = layer_decode(gp["globals"], cfg, h, gck, gcv, pos,
                                   kind="causal", ctx=ctx)
        return h, (lck, lcv, gck, gcv)

    x, (lck, lcv, gck, gcv) = jax.lax.scan(
        group_body, x,
        ({"locals": sp["locals"], "globals": sp["globals"]},
         cache["local_k"], cache["local_v"], cache["global_k"], cache["global_v"]))
    cache = dict(cache, local_k=lck, local_v=lcv, global_k=gck, global_v=gcv)
    if sp.get("tail") is not None:
        x, (tck, tcv) = jax.lax.scan(local_body, x,
                                     (sp["tail"], cache["tail_k"], cache["tail_v"]))
        cache = dict(cache, tail_k=tck, tail_v=tcv)
    return x, cache


# ===================================================================== #
#  VLM grouped stack (N self layers + 1 gated cross-attn layer)          #
# ===================================================================== #
def vlm_stack_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    n_self = cfg.cross_attn_every - 1
    g = cfg.n_layers // cfg.cross_attn_every
    k1, k2, k3 = jax.random.split(key, 3)
    init1 = lambda k: layer_init(k, cfg, dtype)

    def cross_init(k):
        ka, kb = jax.random.split(k)
        return {"ln": rmsnorm_init(cfg.d_model),
                "xattn": attn.cross_attn_init(ka, cfg.attn, cfg.d_model,
                                              cfg.d_vision, dtype),
                "ln2": rmsnorm_init(cfg.d_model),
                "mlp": mlp_init(kb, cfg.d_model, cfg.d_ff, cfg.act, dtype)}

    return {
        "selfs": jax.vmap(lambda k: _stack_init(init1, k, n_self))(
            jax.random.split(k1, g)),                      # (g, n_self, ...)
        "crosses": _stack_init(cross_init, k2, g),         # (g, ...)
    }


def _cross_layer_fwd(cp, cfg, x, vision, impl, chunk, ctx=None):
    h = attn.cross_attention_block(cp["xattn"], cfg.attn,
                                   rmsnorm(cp["ln"], x, cfg.norm_eps),
                                   vision, impl=impl, chunk=chunk, ctx=ctx)
    x = x + h
    x = x + mlp(cp["mlp"], rmsnorm(cp["ln2"], x, cfg.norm_eps), cfg.act)
    return x


def vlm_stack_fwd(sp: Params, cfg: ModelConfig, x, vision, *, ctx, impl,
                  chunk, remat: str, unroll: int = 1, collect_kv: bool = False):
    def self_body(carry, lp):
        h, aux = carry
        h, kv, a = layer_fwd(lp, cfg, h, kind="causal", ctx=ctx, impl=impl,
                             chunk=chunk, return_kv=collect_kv)
        return (h, aux + a), kv

    def group_body(carry, gp):
        carry, kvs = jax.lax.scan(self_body, carry, gp["selfs"])
        h, aux = carry
        h = _cross_layer_fwd(gp["crosses"], cfg, h, vision, impl, chunk, ctx)
        return (h, aux), kvs

    (x, aux), kvs = jax.lax.scan(
        _remat(group_body, remat), (x, jnp.zeros((), jnp.float32)),
        {"selfs": sp["selfs"], "crosses": sp["crosses"]}, unroll=unroll)
    return x, aux, kvs      # (g, n_self, B, S, KVH, D) when collect_kv


def vlm_stack_decode(sp: Params, cfg: ModelConfig, x, cache: Cache, pos, *, ctx):
    def self_body(h, inp):
        lp, ck, cv = inp
        h, ck, cv = layer_decode(lp, cfg, h, ck, cv, pos, kind="causal", ctx=ctx)
        return h, (ck, cv)

    def group_body(h, inp):
        gp, ck, cv, xk, xv = inp
        h, (ck, cv) = jax.lax.scan(self_body, h, (gp["selfs"], ck, cv))
        # cross-attn over cached (pre-projected) vision k/v
        a = cfg.attn
        B = h.shape[0]
        y = rmsnorm(gp["crosses"]["ln"], h, cfg.norm_eps)
        q = jnp.einsum("bsd,de->bse", y, gp["crosses"]["xattn"]["wq"]
                       ).reshape(B, 1, a.n_heads, a.head_dim)
        o = attn.decode_attention(q, xk, xv, kv_len=xk.shape[1],
                                  kind="bidirectional")
        o = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1),
                       gp["crosses"]["xattn"]["wo"])
        h = h + jnp.tanh(gp["crosses"]["xattn"]["gate"]).astype(o.dtype) * o
        h = h + mlp(gp["crosses"]["mlp"],
                    rmsnorm(gp["crosses"]["ln2"], h, cfg.norm_eps), cfg.act)
        return h, (ck, cv)

    x, (ck, cv) = jax.lax.scan(
        group_body, x,
        ({"selfs": sp["selfs"], "crosses": sp["crosses"]},
         cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]))
    return x, dict(cache, k=ck, v=cv)


def vlm_precompute_cross_kv(sp: Params, cfg: ModelConfig, vision):
    """Project vision tokens through every cross layer's k/v once."""
    a = cfg.attn

    def one(cp):
        B, T, _ = vision.shape
        k = jnp.einsum("btd,de->bte", vision, cp["xattn"]["wk"]
                       ).reshape(B, T, a.n_kv_heads, a.head_dim)
        v = jnp.einsum("btd,de->bte", vision, cp["xattn"]["wv"]
                       ).reshape(B, T, a.n_kv_heads, a.head_dim)
        return k, v

    return jax.vmap(one)(sp["crosses"])   # (g, B, T, KVH, D)
