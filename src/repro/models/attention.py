"""GQA/MQA attention: reference oracle, flash-equivalent chunked (flashref),
Pallas dispatch, decode over (possibly sequence-sharded) KV caches, and
cross-attention for VLM layers.

Shape conventions:
  x        (B, S, d_model)
  q        (B, S, H, D)
  k, v     (B, T, KVH, D)
  grouped  (B, S, KVH, G, D) with G = H // KVH
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import Params, apply_rope, dense_init, l2norm

NEG_INF = -1e30


# --------------------------------------------------------------------- #
#  Parameters                                                            #
# --------------------------------------------------------------------- #
def attn_init(key, a: AttentionConfig, d_model: int, d_kv_in: int = 0,
              dtype=jnp.bfloat16) -> Params:
    """Self-attention when d_kv_in == 0, else cross-attention (kv from
    a different width, e.g. vision embeddings)."""
    d_kv_in = d_kv_in or d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d_model, a.n_heads * a.head_dim, dtype),
        "wk": dense_init(k2, d_kv_in, a.n_kv_heads * a.head_dim, dtype),
        "wv": dense_init(k3, d_kv_in, a.n_kv_heads * a.head_dim, dtype),
        "wo": dense_init(k4, a.n_heads * a.head_dim, d_model, dtype),
    }
    if a.qk_norm:
        p["q_norm"] = jnp.ones((a.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((a.head_dim,), jnp.float32)
    return p


def cross_attn_init(key, a: AttentionConfig, d_model: int, d_vision: int,
                    dtype=jnp.bfloat16) -> Params:
    p = attn_init(key, a, d_model, d_kv_in=d_vision, dtype=dtype)
    p["gate"] = jnp.zeros((), jnp.float32)   # tanh-gated residual (llama3.2)
    return p


def project_qkv(p: Params, a: AttentionConfig, x: jnp.ndarray,
                kv_x: Optional[jnp.ndarray] = None,
                positions: Optional[jnp.ndarray] = None,
                rope: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    kv_x = x if kv_x is None else kv_x
    B, S, _ = x.shape
    T = kv_x.shape[1]
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, a.n_heads, a.head_dim)
    k = jnp.einsum("btd,de->bte", kv_x, p["wk"]).reshape(B, T, a.n_kv_heads, a.head_dim)
    v = jnp.einsum("btd,de->bte", kv_x, p["wv"]).reshape(B, T, a.n_kv_heads, a.head_dim)
    if a.qk_norm:
        q = l2norm(q) * p["q_norm"].astype(q.dtype)
        k = l2norm(k) * p["k_norm"].astype(k.dtype)
    if rope:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


# --------------------------------------------------------------------- #
#  Masks                                                                 #
# --------------------------------------------------------------------- #
def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, kind: str,
               window: int) -> jnp.ndarray:
    """(..., S, C) additive bias; q_pos (S,), k_pos (C,)."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    if kind == "bidirectional":
        ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    elif kind == "causal":
        ok = dk <= dq
    elif kind == "local":
        ok = (dk <= dq) & (dk > dq - window)
    else:
        raise ValueError(kind)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# --------------------------------------------------------------------- #
#  Reference (oracle) attention                                          #
# --------------------------------------------------------------------- #
def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """GQA: repeat KV heads to full head count. Under SPMD this is the
    sharding-friendly form — a grouped (KVH, G) reshape of a head-sharded
    q is unrepresentable when KVH < the model-axis size and forces full
    rematerialization; the repeat keeps every einsum head-sharded with
    zero extra communication (k/v are replicated across the model axis)."""
    KVH = k.shape[2]
    if KVH == n_heads:
        return k
    # gather (not jnp.repeat): repeat's internal (KVH, G) reshape is itself
    # unrepresentable under head sharding; a gather shards by index slice.
    idx = jnp.arange(n_heads) // (n_heads // KVH)
    return jnp.take(k, idx, axis=2)


def reference_attention(q, k, v, kind: str = "causal", window: int = 0,
                        softcap: float = 0.0) -> jnp.ndarray:
    B, S, H, D = q.shape
    T = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    s = jnp.einsum("bshd,bthd->bhst", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = s + _mask_bias(jnp.arange(S), jnp.arange(T), kind, window)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v)
    return o.reshape(B, S, H, D)


# --------------------------------------------------------------------- #
#  flashref: chunked online-softmax attention (flash-equivalent HLO)     #
# --------------------------------------------------------------------- #
def _shard(x, ctx, *spec):
    """with_sharding_constraint helper; drops axes that don't divide."""
    if ctx is None or getattr(ctx, "mesh", None) is None:
        return x
    import numpy as _np
    mesh = ctx.mesh
    clean = []
    for dim, ax in enumerate(spec):
        if ax is None:
            clean.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(_np.prod([mesh.shape[a] for a in axes]))
        clean.append(ax if x.shape[dim] % size == 0 else None)
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*clean))
    return jax.lax.with_sharding_constraint(x, sh)


def flashref_attention(q, k, v, kind: str = "causal", window: int = 0,
                       chunk: int = 1024, softcap: float = 0.0,
                       ctx=None) -> jnp.ndarray:
    """Online-softmax over KV chunks via lax.scan; never materializes the
    full (S, T) score matrix. Matches reference_attention to ~1e-3 (bf16).

    GSPMD note: sharding propagation through the chunk-scan carry is weak,
    so q/k/v and the carry inits carry explicit head-sharded constraints
    (batch over data axes, heads over the model axis)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    if ctx is not None and getattr(ctx, "mesh", None) is not None:
        da, ma = ctx.data_axes, ctx.model_axis
        q = _shard(q, ctx, da, None, ma, None)
        k = _shard(k, ctx, da, None, ma, None)
        v = _shard(v, ctx, da, None, ma, None)
    chunk = min(chunk, T)
    if T % chunk:                      # pad KV to a chunk multiple
        pad = chunk - T % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = k.shape[1]
    n = Tp // chunk
    k_c = jnp.moveaxis(k.reshape(B, n, chunk, H, D), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, n, chunk, H, D), 1, 0)
    kpos_c = jnp.arange(Tp).reshape(n, chunk)
    valid_c = (kpos_c < T)
    q_pos = jnp.arange(S)
    scale = 1.0 / math.sqrt(D)

    def body(carry, inp):
        m, l, o = carry                                    # (B,H,S) / (B,H,S,D)
        kc, vc, kpos, valid = inp
        s = jnp.einsum("bshd,bchd->bhsc", q, kc,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        bias = _mask_bias(q_pos, kpos, kind, window)
        bias = jnp.where(valid[None, :], bias, NEG_INF)
        s = s + bias                                       # (B,H,S,C)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    o0 = jnp.zeros((B, H, S, D), jnp.float32)
    if ctx is not None and getattr(ctx, "mesh", None) is not None:
        da, ma = ctx.data_axes, ctx.model_axis
        m0 = _shard(m0, ctx, da, ma, None)
        l0 = _shard(l0, ctx, da, ma, None)
        o0 = _shard(o0, ctx, da, ma, None, None)
        k_c = _shard(k_c, ctx, None, da, None, ma, None)
        v_c = _shard(v_c, ctx, None, da, None, ma, None)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (k_c, v_c, kpos_c, valid_c))
    out = o / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 1, 2)                          # (B,S,H,D)
    return out.astype(q.dtype)


# --------------------------------------------------------------------- #
#  Decode attention (one new token against a KV cache)                   #
# --------------------------------------------------------------------- #
def decode_attention(q, cache_k, cache_v, kv_len, q_pos=None,
                     kind: str = "causal", window: int = 0,
                     softcap: float = 0.0) -> jnp.ndarray:
    """q: (B, 1, H, D); cache_{k,v}: (B, Smax, KVH, D); kv_len: () or (B,).

    Works with ``Smax`` sequence-sharded across a mesh axis: the reductions
    over T lower to cheap activation-sized partial-sum collectives
    (flash-decode-style SP).
    """
    B, _, H, D = q.shape
    T, KVH = cache_k.shape[1], cache_k.shape[2]
    G = H // KVH
    # GROUPED einsum, not KV expansion: with one query token the grouped
    # reshape of q is a free reshard (q is ~MBs), while expanding the KV
    # cache to H heads would re-materialize it G x (§Perf C1: 270GB ->
    # 8GB per decode step for llama3-405b at 32k).
    qg = q.reshape(B, KVH, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, cache_k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    kv_len = jnp.asarray(kv_len)
    lens = kv_len[..., None] if kv_len.ndim else kv_len    # (B,1) or ()
    tpos = jnp.arange(T)
    ok = tpos[None, :] < jnp.broadcast_to(lens, (B, 1))    # (B, T)
    if kind == "local" and window:
        # ring-buffer local cache: all (< kv_len) slots valid; kv_len<=window
        ok = ok & (tpos[None, :] >= jnp.broadcast_to(lens, (B, 1)) - window)
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", w.astype(cache_v.dtype), cache_v)
    return o.reshape(B, 1, H, D)


# --------------------------------------------------------------------- #
#  Dispatch                                                              #
# --------------------------------------------------------------------- #
def run_attention(q, k, v, *, impl: str = "auto", kind: str = "causal",
                  window: int = 0, chunk: int = 1024,
                  softcap: float = 0.0, ctx=None) -> jnp.ndarray:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "flashref"
    if impl == "reference":
        return reference_attention(q, k, v, kind, window, softcap)
    if impl == "flashref":
        return flashref_attention(q, k, v, kind, window, chunk, softcap, ctx)
    if impl == "pallas":
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, kind=kind, window=window,
                                   softcap=softcap)
    raise ValueError(f"unknown attention impl {impl!r}")


def self_attention_block(p: Params, a: AttentionConfig, x: jnp.ndarray,
                         *, kind: str, impl: str = "auto",
                         chunk: int = 1024,
                         positions: Optional[jnp.ndarray] = None,
                         ctx=None
                         ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full self-attn block (proj -> attn -> out proj). Returns (out, (k, v))
    so prefill can populate the cache."""
    q, k, v = project_qkv(p, a, x, positions=positions)
    o = run_attention(q, k, v, impl=impl, kind=kind, window=a.local_window,
                      chunk=chunk, softcap=a.softcap, ctx=ctx)
    B, S = x.shape[:2]
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), p["wo"])
    return out, (k, v)


def cross_attention_block(p: Params, a: AttentionConfig, x: jnp.ndarray,
                          vision: jnp.ndarray, impl: str = "auto",
                          chunk: int = 1024, ctx=None) -> jnp.ndarray:
    """Tanh-gated cross attention over (precomputed) vision tokens."""
    q, k, v = project_qkv(p, a, x, kv_x=vision, rope=False)
    o = run_attention(q, k, v, impl="flashref" if impl == "pallas" else impl,
                      kind="bidirectional", chunk=chunk, ctx=ctx)
    B, S = x.shape[:2]
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, S, -1), p["wo"])
    return jnp.tanh(p["gate"]).astype(out.dtype) * out


def chunk_attention(q, cache_k, cache_v, pos0, softcap: float = 0.0
                    ) -> jnp.ndarray:
    """Chunked-prefill attention: C new queries (absolute positions
    pos0..pos0+C-1) over a cache whose first pos0+C slots are valid.
    q: (B, C, H, D); cache_{k,v}: (B, Smax, KVH, D); pos0: scalar."""
    B, C, H, D = q.shape
    T, KVH = cache_k.shape[1], cache_k.shape[2]
    G = H // KVH
    qg = q.reshape(B, C, KVH, G, D)        # grouped: avoid expanding cache
    s = jnp.einsum("bckgd,btkd->bkgct", qg, cache_k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = pos0 + jnp.arange(C)
    ok = jnp.arange(T)[None, :] <= q_pos[:, None]        # causal (C, T)
    s = s + jnp.where(ok, 0.0, NEG_INF)[None, None, None]
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgct,btkd->bckgd", w.astype(cache_v.dtype), cache_v)
    return o.reshape(B, C, H, D)


def extend_self_attention(p: Params, a: AttentionConfig, x: jnp.ndarray,
                          cache_k, cache_v, pos0
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Chunked-prefill step for one self-attn block: project C tokens,
    write their k/v at [pos0:pos0+C], attend over the whole prefix."""
    B, C = x.shape[:2]
    positions = jnp.broadcast_to(pos0 + jnp.arange(C)[None, :], (B, C))
    q, k, v = project_qkv(p, a, x, positions=positions)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos0, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos0, axis=1)
    o = chunk_attention(q, cache_k, cache_v, pos0, softcap=a.softcap)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, C, -1), p["wo"])
    return out, cache_k, cache_v


def write_kv(cache: jnp.ndarray, new: jnp.ndarray, idx) -> jnp.ndarray:
    """Write (B,1,KVH,D) into (B,Smax,KVH,D) at slot `idx` (scalar or (B,))."""
    idx = jnp.asarray(idx)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype),
                                                   idx, axis=1)
    return jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), i, axis=0))(cache, new, idx)


def decode_self_attention(p: Params, a: AttentionConfig, x: jnp.ndarray,
                          cache_k, cache_v, pos, *, kind: str = "causal"
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode step for a self-attention block.

    x: (B, 1, d); cache_{k,v}: (B, Smax, KVH, D); pos: scalar or (B,) —
    absolute position of the new token. Local layers use a ring buffer of
    size `a.local_window` (write slot = pos % Smax, all warm slots valid).
    Returns (block_out, cache_k, cache_v).
    """
    B = x.shape[0]
    smax = cache_k.shape[1]
    pos = jnp.asarray(pos)
    positions = jnp.broadcast_to(pos.reshape(-1, 1) if pos.ndim else pos[None, None],
                                 (B, 1))
    q, k, v = project_qkv(p, a, x, positions=positions)
    slot = positions[:, 0] % smax if kind == "local" else positions[:, 0]
    if pos.ndim == 0:
        slot = slot[0]
    cache_k = write_kv(cache_k, k, slot)
    cache_v = write_kv(cache_v, v, slot)
    kv_len = jnp.minimum(positions[:, 0] + 1, smax)
    o = decode_attention(q, cache_k, cache_v, kv_len,
                         kind="causal" if kind == "local" else kind,
                         softcap=a.softcap)
    out = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, -1), p["wo"])
    return out, cache_k, cache_v
