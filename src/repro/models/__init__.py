from repro.models.model import Model, build_model  # noqa: F401
from repro.models.moe import LOCAL_CTX, ParallelContext  # noqa: F401
