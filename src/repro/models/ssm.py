"""State-space model blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

Both use *chunked* sequence processing so the (d_inner, d_state) expanded
state is only materialized per-chunk (the jnp analogue of the fused CUDA
selective-scan — on TPU the Pallas kernel in ``repro.kernels.ssm_scan``
replaces the inner loop; this module is also its oracle).

Shapes: u (B, S, d_model); mamba1 state h (B, d_inner, d_state);
mamba2 state h (B, n_heads, head_p, d_state).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init


def _dt_rank(d_model: int) -> int:
    return max(1, math.ceil(d_model / 16))


# ===================================================================== #
#  Causal depthwise conv1d (kernel k, shift-and-add form)                #
# ===================================================================== #
def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C); w: (C, K); b: (C,). Causal depthwise conv + silu."""
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    y = sum(xp[:, i:i + S, :] * w[:, i] for i in range(K))
    return jax.nn.silu(y + b)


def conv1d_step(conv_state: jnp.ndarray, x_new: jnp.ndarray, w: jnp.ndarray,
                b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """conv_state: (B, K-1, C); x_new: (B, C). Returns (new_state, y (B,C))."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window, w)
    return window[:, 1:, :], jax.nn.silu(y + b)


# ===================================================================== #
#  Mamba-1                                                               #
# ===================================================================== #
def mamba1_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """x/z projections are SEPARATE params: a fused (d, 2di) projection
    must be split along the model-sharded output dim, which forces a
    collective-permute every layer (§Perf iteration A2)."""
    d, s = cfg.d_model, cfg.ssm
    di = s.expand * d
    r = _dt_rank(d)
    ks = jax.random.split(key, 7)
    return {
        "in_x": dense_init(ks[6], d, di, dtype),
        "in_z": dense_init(ks[0], d, di, dtype),
        "conv_w": (jax.random.normal(ks[1], (di, s.d_conv), jnp.float32)
                   * (1.0 / math.sqrt(s.d_conv))).astype(jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], di, r + 2 * s.d_state, dtype),
        "dt_proj": dense_init(ks[3], r, di, jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _mamba1_inputs(p: Params, cfg: ModelConfig, u: jnp.ndarray):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    r = _dt_rank(cfg.d_model)
    x = jnp.einsum("bsd,de->bse", u, p["in_x"])
    z = jnp.einsum("bsd,de->bse", u, p["in_z"])
    x = causal_conv1d(x, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    dbc = jnp.einsum("bsc,ce->bse", x, p["x_proj"])
    dt_in, B, C = jnp.split(dbc, [r, r + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rc->bsc", dt_in.astype(jnp.float32), p["dt_proj"])
        + p["dt_bias"])                                      # (B,S,di) f32
    A = -jnp.exp(p["A_log"])                                 # (di, N) f32
    return x, z, dt, A, B.astype(jnp.float32), C.astype(jnp.float32)


def mamba1_scan(x, dt, A, B, C, chunk: int, ctx=None):
    """Selective scan. x (B,S,di); dt (B,S,di) f32; A (di,N); B,C (B,S,N).
    Returns (y (B,S,di) f32, h_final (B,di,N)).

    Sequential lax.scan over time: the expanded (di, N) state lives only
    in the loop carry — the jnp analogue of the fused selective-scan
    kernel (repro.kernels.ssm_scan keeps it in VMEM on TPU). §Perf
    iteration A1: an associative_scan formulation materializes an
    O(log c) slice tree of (B, c, di, N) tensors — ~60x the HBM traffic
    of this form (203TB -> ~4TB per train step for falcon-mamba)."""
    Bb, S, di = x.shape
    N = A.shape[1]
    # the scan state is f32 by contract; pin the streamed inputs too so
    # f64 callers (x64 mode, enabled by the jax solver backend) don't
    # promote the carry mid-scan
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                                # (B,di),(B,N)
        dA = jnp.exp(dtt[..., None] * A)                     # (B,di,N)
        dBx = (dtt * xt.astype(jnp.float32))[..., None] * Bt[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    from repro.models.attention import _shard
    da, ma = ((ctx.data_axes, ctx.model_axis) if ctx is not None
              and getattr(ctx, "mesh", None) is not None else (None, None))
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    h0 = jnp.zeros((Bb, di, N), jnp.float32)
    if ma is not None:
        # while-carry sharding propagation is weak: pin the expanded state
        # and the streamed xs to (batch@data, channels@model) (§Perf A3)
        h0 = _shard(h0, ctx, da, ma, None)
        xs = tuple(_shard(u, ctx, None, da, ma) if u.ndim == 3 else u
                   for u in xs)
    hT, ys = jax.lax.scan(step, h0, xs)
    ys = jnp.moveaxis(ys, 0, 1)
    if ma is not None:
        ys = _shard(ys, ctx, da, None, ma)
    return ys, hT


def mamba1_forward(p: Params, cfg: ModelConfig, u: jnp.ndarray,
                   ctx=None) -> jnp.ndarray:
    x, z, dt, A, B, C = _mamba1_inputs(p, cfg, u)
    y, _ = mamba1_scan(x, dt, A, B, C, cfg.ssm.chunk_size, ctx=ctx)
    y = y + p["D"] * x.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return jnp.einsum("bsc,cd->bsd", y, p["out_proj"])


def mamba1_init_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di), jnp.bfloat16),
        "h": jnp.zeros((batch, di, s.d_state), jnp.float32),
    }


def mamba1_step(p: Params, cfg: ModelConfig, u: jnp.ndarray,
                state: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    """u: (B, 1, d). Returns (out (B,1,d), new_state)."""
    s = cfg.ssm
    r = _dt_rank(cfg.d_model)
    x = jnp.einsum("bsd,de->bse", u, p["in_x"])[:, 0]
    z = jnp.einsum("bsd,de->bse", u, p["in_z"])[:, 0]
    conv, x = conv1d_step(state["conv"], x, p["conv_w"].astype(x.dtype),
                          p["conv_b"].astype(x.dtype))
    dbc = jnp.einsum("bc,ce->be", x, p["x_proj"])
    dt_in, B, C = jnp.split(dbc, [r, r + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,rc->bc", dt_in.astype(jnp.float32), p["dt_proj"])
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)                          # (B,di,N)
    dBx = (dt * x.astype(jnp.float32))[..., None] * B.astype(jnp.float32)[:, None, :]
    h = dA * state["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32))
    y = y + p["D"] * x.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"])[:, None, :]
    return out, {"conv": conv, "h": h}


# ===================================================================== #
#  Mamba-2 (SSD, scalar A per head, n_groups = 1)                        #
# ===================================================================== #
def mamba2_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    """Projections are split (zx / bc / dt) so TP sharding is clean:
    z,x,dt shard with the heads over `model`; B,C stay replicated."""
    d, s = cfg.d_model, cfg.ssm
    di = s.expand * d
    H = s.n_heads
    N = s.d_state
    ks = jax.random.split(key, 7)
    return {
        "in_z": dense_init(ks[0], d, di, dtype),
        "in_x": dense_init(ks[6], d, di, dtype),
        "in_bc": dense_init(ks[1], d, 2 * N, dtype),
        "in_dt": dense_init(ks[2], d, H, dtype),
        "conv_x_w": (jax.random.normal(ks[3], (di, s.d_conv), jnp.float32)
                     * (1.0 / math.sqrt(s.d_conv))),
        "conv_x_b": jnp.zeros((di,), jnp.float32),
        "conv_bc_w": (jax.random.normal(jax.random.fold_in(ks[3], 1),
                                        (2 * N, s.d_conv), jnp.float32)
                      * (1.0 / math.sqrt(s.d_conv))),
        "conv_bc_b": jnp.zeros((2 * N,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[4], (H,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "A_log": jnp.log(jax.random.uniform(ks[5], (H,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(di),
        "out_proj": dense_init(jax.random.fold_in(key, 7), di, d, dtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., L). Returns (..., L, L) with out[i,j] = sum_{j<k<=i} x[k],
    -inf above diagonal."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, ctx=None):
    """Mamba-2 SSD. x (b,s,h,p); dt (b,s,h) f32; A (h,); B,C (b,s,n).
    Returns y (b,s,h,p) f32 and final state (b,h,p,n)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, N).astype(jnp.float32)
    dA = dtc * A                                              # (b,c,l,h)
    dA_cum = jnp.cumsum(dA, axis=2)
    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))            # (b,c,h,l,l)
    xdt = xc.astype(jnp.float32) * dtc[..., None]             # (b,c,l,h,p)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)            # (b,c,l,m)
    y_diag = jnp.einsum("bclm,bchlm,bcmhp->bclhp", scores, L, xdt)
    # 2) chunk states
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)     # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xdt)
    # 3) inter-chunk recurrence (sequential over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                # (b,c,h)

    def body(h_prev, inp):
        st, dec = inp                                         # (b,h,p,n), (b,h)
        h_in = h_prev
        h_next = dec[..., None, None] * h_prev + st
        return h_next, h_in

    h0 = jnp.zeros((b, H, P, N), jnp.float32)
    if ctx is not None and getattr(ctx, "mesh", None) is not None:
        from repro.models.attention import _shard
        h0 = _shard(h0, ctx, ctx.data_axes, ctx.model_axis, None, None)
    hT, h_in = jax.lax.scan(body, h0,
                            (jnp.moveaxis(states, 1, 0),
                             jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                           # (b,c,h,p,n)
    # 4) state -> output within chunk
    state_decay = jnp.exp(dA_cum)                             # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, h_in, state_decay)
    y = (y_diag + y_off).reshape(b, Sp, H, P)[:, :S]
    return y, hT


def _mamba2_project(p: Params, cfg: ModelConfig, u: jnp.ndarray):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    N = s.d_state
    z = jnp.einsum("bsd,de->bse", u, p["in_z"])
    x = jnp.einsum("bsd,de->bse", u, p["in_x"])
    bc = jnp.einsum("bsd,de->bse", u, p["in_bc"])
    dt_in = jnp.einsum("bsd,de->bse", u, p["in_dt"])
    x = causal_conv1d(x, p["conv_x_w"].astype(x.dtype),
                      p["conv_x_b"].astype(x.dtype))
    bc = causal_conv1d(bc, p["conv_bc_w"].astype(bc.dtype),
                       p["conv_bc_b"].astype(bc.dtype))
    B, C = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])
    return z, x, B, C, dt


def mamba2_forward(p: Params, cfg: ModelConfig, u: jnp.ndarray,
                   ctx=None) -> jnp.ndarray:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H, N = s.n_heads, s.d_state
    P = di // H
    z, x, B, C, dt = _mamba2_project(p, cfg, u)
    A = -jnp.exp(p["A_log"])
    Bsz, S = u.shape[:2]
    y, _ = ssd_chunked(x.reshape(Bsz, S, H, P), dt, A, B, C, s.chunk_size,
                       ctx=ctx)
    y = y + p["D"][:, None] * x.reshape(Bsz, S, H, P).astype(jnp.float32)
    y = y.reshape(Bsz, S, di)
    y = rmsnorm(p["norm"], (y * jax.nn.silu(z.astype(jnp.float32))
                            ).astype(u.dtype), cfg.norm_eps)
    return jnp.einsum("bsc,cd->bsd", y, p["out_proj"])


def mamba2_init_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H, N = s.n_heads, s.d_state
    P = di // H
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, di), jnp.bfloat16),
        "conv_bc": jnp.zeros((batch, s.d_conv - 1, 2 * N), jnp.bfloat16),
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def mamba2_step(p: Params, cfg: ModelConfig, u: jnp.ndarray,
                state: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H, N = s.n_heads, s.d_state
    P = di // H
    z = jnp.einsum("bsd,de->bse", u, p["in_z"])[:, 0]
    x = jnp.einsum("bsd,de->bse", u, p["in_x"])[:, 0]
    bc = jnp.einsum("bsd,de->bse", u, p["in_bc"])[:, 0]
    dt_in = jnp.einsum("bsd,de->bse", u, p["in_dt"])[:, 0]
    conv_x, x = conv1d_step(state["conv_x"], x, p["conv_x_w"].astype(x.dtype),
                            p["conv_x_b"].astype(x.dtype))
    conv_bc, bc = conv1d_step(state["conv_bc"], bc,
                              p["conv_bc_w"].astype(bc.dtype),
                              p["conv_bc_b"].astype(bc.dtype))
    B, C = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])   # (b,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                             # (b,H)
    xh = x.reshape(-1, H, P).astype(jnp.float32)
    dBx = (dt[..., None] * xh)[..., None] * B.astype(jnp.float32)[:, None, None, :]
    h = dA[..., None, None] * state["h"] + dBx                       # (b,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", h, C.astype(jnp.float32))
    y = y + p["D"][:, None] * xh
    y = y.reshape(-1, di)
    y = rmsnorm(p["norm"], (y * jax.nn.silu(z.astype(jnp.float32))
                            ).astype(u.dtype), cfg.norm_eps)
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"])[:, None, :]
    return out, {"conv_x": conv_x, "conv_bc": conv_bc, "h": h}
