"""Mixture-of-Experts FFN with sort-based grouped dispatch.

Design (TPU/pjit-native — see DESIGN.md §5):
  * tokens stay sharded over the data axes; experts are sharded over the
    `model` axis (EP).  Each device keeps its local tokens, selects the
    subset routed to its *local* experts (sort + capacity buffer), runs the
    grouped expert matmuls, and the per-token combine is a single
    activation-sized ``psum`` over the model axis — no token all-to-all.
  * one-hot (T,E,C) GShard dispatch is O(T·E·C) memory and infeasible at
    top-6/64-expert scale; the sort-based path is O(T·k·d).

Two entry points share the same math:
  ``moe_ffn_local``  — single-device / oracle path (E_local = E).
  ``moe_ffn``        — shard_map path over (data…, model) for EP.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init


class ParallelContext(NamedTuple):
    """How model-internal collectives see the mesh. mesh=None => local."""
    mesh: Optional[object] = None
    data_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    # tp2d decode: weights are (d@data, ff@model); activations hop between
    # batch-sharded (attention/cache) and feature-sharded (MLP) layouts —
    # decode-sized reshards instead of weight-sized all-gathers (§Perf C2)
    feature_shard_decode: bool = False

    @property
    def n_model_shards(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def n_data_shards(self) -> int:
        if self.mesh is None:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.data_axes) or 1


LOCAL_CTX = ParallelContext()


def moe_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   * (1.0 / math.sqrt(f))).astype(dtype),
    }
    if m.n_shared_experts:
        fs = m.n_shared_experts * f
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d, fs, dtype),
            "w_up": dense_init(k2, d, fs, dtype),
            "w_down": dense_init(k3, fs, d, dtype),
        }
    return p


def capacity(n_tokens_local: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(n_tokens_local * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, -(-c // 8) * 8)      # round up to a multiple of 8


# --------------------------------------------------------------------- #
#  Grouped dispatch for one shard                                        #
# --------------------------------------------------------------------- #
def _dispatch_compute_combine(x_flat, gates, ids, wg, wu, wd,
                              expert_lo: int, n_local: int, cap: int,
                              act: str = "silu"):
    """x_flat (T,d); gates/ids (T,k); expert weights are the LOCAL slice
    (n_local, d, f). Returns partial output (T, d) covering local experts."""
    T, d = x_flat.shape
    k = ids.shape[1]
    flat_ids = ids.reshape(-1)                           # (T*k,)
    flat_gate = gates.reshape(-1)
    local_ids = flat_ids - expert_lo
    is_local = (local_ids >= 0) & (local_ids < n_local)
    sort_key = jnp.where(is_local, local_ids, n_local)   # drop bucket last
    order = jnp.argsort(sort_key)                        # stable
    sorted_ids = sort_key[order]
    # position within each expert group
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_local + 1))
    pos = jnp.arange(T * k) - starts[jnp.clip(sorted_ids, 0, n_local)]
    keep = (sorted_ids < n_local) & (pos < cap)
    slot = jnp.where(keep, sorted_ids * cap + pos, n_local * cap)
    tok = order // k                                     # source token index
    buf = jnp.zeros((n_local * cap + 1, d), x_flat.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x_flat[tok], 0))
    h_in = buf[:-1].reshape(n_local, cap, d)
    g = jnp.einsum("ecd,edf->ecf", h_in, wg)
    u = jnp.einsum("ecd,edf->ecf", h_in, wu)
    h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, wd).reshape(n_local * cap, d)
    contrib = out_e[jnp.where(keep, slot, n_local * cap - 1)]
    contrib = jnp.where(keep[:, None], contrib * flat_gate[order][:, None].astype(contrib.dtype), 0)
    out = jnp.zeros((T, d), x_flat.dtype).at[tok].add(contrib)
    return out


def _route(router, x_flat, cfg: ModelConfig):
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    # pin one_hot to the routing dtype: its float_ default is f64 under
    # x64 (the solver backend enables it), which would leak into the
    # f32 aux-loss scan carry
    ce = jnp.mean(jax.nn.one_hot(ids, m.n_experts,
                                 dtype=probs.dtype).sum(axis=1), axis=0)
    aux = m.n_experts * jnp.sum(me * ce)
    return gates, ids, aux


def moe_ffn_local(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    """Oracle / single-device path."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    gates, ids, aux = _route(p["router"], xf, cfg)
    cap = capacity(xf.shape[0], cfg)
    out = _dispatch_compute_combine(xf, gates, ids, p["w_gate"], p["w_up"],
                                    p["w_down"], 0, cfg.moe.n_experts, cap,
                                    cfg.act if cfg.act != "geglu" else "gelu")
    out = out + _shared_ffn(p, cfg, xf)
    return out.reshape(B, S, d), aux


def _shared_ffn(p: Params, cfg: ModelConfig, xf: jnp.ndarray) -> jnp.ndarray:
    if not cfg.moe.n_shared_experts:
        return jnp.zeros_like(xf)
    sp = p["shared"]
    g = jnp.einsum("td,df->tf", xf, sp["w_gate"])
    u = jnp.einsum("td,df->tf", xf, sp["w_up"])
    return jnp.einsum("tf,fd->td", jax.nn.silu(g) * u, sp["w_down"])


def moe_ffn(p: Params, cfg: ModelConfig, x: jnp.ndarray,
            ctx: ParallelContext):
    """EP path: experts sharded over ctx.model_axis via shard_map."""
    if ctx.mesh is None or ctx.n_model_shards == 1:
        return moe_ffn_local(p, cfg, x)
    B, S, d = x.shape
    E = cfg.moe.n_experts
    n_model = ctx.n_model_shards
    assert E % n_model == 0, f"experts {E} not divisible by model axis {n_model}"
    n_local = E // n_model
    t_local = (B * S) // ctx.n_data_shards
    cap = capacity(t_local, cfg)
    act = cfg.act if cfg.act != "geglu" else "gelu"
    batch_spec = P(ctx.data_axes if ctx.data_axes else None)
    ax = ctx.model_axis

    def shard_fn(xs, router, wg, wu, wd):
        Bl, Sl, _ = xs.shape
        xf = xs.reshape(-1, d)
        gates, ids, aux = _route(router, xf, cfg)
        idx = jax.lax.axis_index(ax)
        out = _dispatch_compute_combine(xf, gates, ids, wg, wu, wd,
                                        idx * n_local, n_local, cap, act)
        out = jax.lax.psum(out, ax)
        aux = jax.lax.pmean(aux, ax)
        for a in ctx.data_axes:
            aux = jax.lax.pmean(aux, a)
        return out.reshape(Bl, Sl, d), aux

    from jax.experimental.shard_map import shard_map
    out, aux = shard_map(
        shard_fn, mesh=ctx.mesh,
        in_specs=(batch_spec, P(), P(ax), P(ax), P(ax)),
        out_specs=(batch_spec, P()),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = out + _shared_ffn(p, cfg, x.reshape(-1, d)).reshape(B, S, d)
    return out, aux
