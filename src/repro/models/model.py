"""Model facade: uniform API over all architecture families.

  m = build_model(cfg)
  params = m.init(key)
  loss, aux = m.loss_fn(params, batch, ctx=...)
  logits, cache = m.prefill(params, batch, max_len, ctx=...)
  logits, cache = m.decode_step(params, tokens, cache, pos, ctx=...)
  cache = m.init_cache(batch, max_len, abstract=True)   # dry-run stand-ins

Batches:  LM {tokens, labels}; VLM adds {vision}; audio {frames, labels}.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid as hyb
from repro.models import transformer as tfm
from repro.models.layers import embed, embed_init, rmsnorm, rmsnorm_init, softmax_cross_entropy, unembed
from repro.models.moe import LOCAL_CTX, ParallelContext

Batch = Dict[str, jnp.ndarray]


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable            # (params, batch, ctx) -> (loss, aux)
    forward: Callable            # (params, batch, ctx) -> logits
    prefill: Callable            # (params, batch, max_len, ctx) -> (logits, cache)
    decode_step: Callable        # (params, tokens, cache, pos, ctx) -> (logits, cache)
    init_cache: Callable         # (batch_size, max_len, abstract) -> cache


def _kv_dtype(cfg):
    return jnp.bfloat16


def build_model(cfg: ModelConfig) -> Model:
    dtype = jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32
    a = cfg.attn

    # ----------------------------- init ------------------------------- #
    def init(key):
        k_emb, k_stack, k_ln = jax.random.split(key, 3)
        p = {"embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model,
                                 cfg.tie_embeddings, dtype),
             "final_ln": rmsnorm_init(cfg.d_model)}
        if cfg.family in ("dense", "moe", "audio"):
            if cfg.attn.pattern == "local_global":
                p["stack"] = tfm.lg_stack_init(k_stack, cfg, dtype)
            else:
                p["stack"] = tfm.uniform_stack_init(k_stack, cfg, dtype)
        elif cfg.family == "vlm":
            p["stack"] = tfm.vlm_stack_init(k_stack, cfg, dtype)
        elif cfg.family == "ssm":
            p["stack"] = hyb.ssm_stack_init(k_stack, cfg, dtype)
        elif cfg.family == "hybrid":
            p["stack"] = hyb.hybrid_stack_init(k_stack, cfg, dtype)
        else:
            raise ValueError(cfg.family)
        return p

    # --------------------------- embedding ---------------------------- #
    def _embed_in(p, batch):
        if cfg.family == "audio":
            return batch["frames"].astype(dtype)
        x = embed(p["embed"], batch["tokens"], scale_by_dim=cfg.embed_scale)
        return x

    def _stack_fwd(p, x, batch, ctx, collect_kv=False):
        impl = cfg.attn_impl
        kw = dict(ctx=ctx, impl=impl, chunk=1024, remat=cfg.remat_policy,
                  collect_kv=collect_kv)
        if cfg.family in ("dense", "moe", "audio"):
            if cfg.attn.pattern == "local_global":
                return tfm.lg_stack_fwd(p["stack"], cfg, x, **kw)
            return tfm.uniform_stack_fwd(p["stack"], cfg, x, **kw)
        if cfg.family == "vlm":
            return tfm.vlm_stack_fwd(p["stack"], cfg, x,
                                     batch["vision"].astype(dtype), **kw)
        if cfg.family == "ssm":
            return hyb.ssm_stack_fwd(p["stack"], cfg, x,
                                     remat=cfg.remat_policy, ctx=ctx)
        if cfg.family == "hybrid":
            return hyb.hybrid_stack_fwd(p["stack"], cfg, x, **kw)
        raise ValueError(cfg.family)

    # ----------------------------- train ------------------------------ #
    def forward(p, batch, ctx: ParallelContext = LOCAL_CTX):
        x = _embed_in(p, batch)
        x, aux, _ = _stack_fwd(p, x, batch, ctx)
        x = rmsnorm(p["final_ln"], x, cfg.norm_eps)
        return unembed(p["embed"], x), aux

    def loss_fn(p, batch, ctx: ParallelContext = LOCAL_CTX):
        logits, aux = forward(p, batch, ctx)
        mask = batch.get("loss_mask")
        loss = softmax_cross_entropy(logits, batch["labels"], mask)
        if cfg.family == "moe":
            loss = loss + 0.01 * aux
        return loss, {"ce": loss, "aux": aux}

    # ---------------------------- caches ------------------------------ #
    def init_cache(batch_size: int, max_len: int, abstract: bool = False):
        mk = ((lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract
              else (lambda s, d: jnp.zeros(s, d)))
        kvd = _kv_dtype(cfg)
        B, L = batch_size, cfg.n_layers
        KVH, D = a.n_kv_heads, a.head_dim

        if cfg.family in ("dense", "moe"):
            if a.pattern == "local_global":
                g, tail = tfm.lg_split(cfg)
                W = min(a.local_window, max_len)
                c = {"local_k": mk((g, a.local_ratio, B, W, KVH, D), kvd),
                     "local_v": mk((g, a.local_ratio, B, W, KVH, D), kvd),
                     "global_k": mk((g, B, max_len, KVH, D), kvd),
                     "global_v": mk((g, B, max_len, KVH, D), kvd)}
                if tail:
                    c["tail_k"] = mk((tail, B, W, KVH, D), kvd)
                    c["tail_v"] = mk((tail, B, W, KVH, D), kvd)
                return c
            return {"k": mk((L, B, max_len, KVH, D), kvd),
                    "v": mk((L, B, max_len, KVH, D), kvd)}
        if cfg.family == "vlm":
            g = cfg.n_layers // cfg.cross_attn_every
            ns = cfg.cross_attn_every - 1
            return {"k": mk((g, ns, B, max_len, KVH, D), kvd),
                    "v": mk((g, ns, B, max_len, KVH, D), kvd),
                    "cross_k": mk((g, B, cfg.n_vision_tokens, KVH, D), kvd),
                    "cross_v": mk((g, B, cfg.n_vision_tokens, KVH, D), kvd)}
        def _ssm_state(lead):
            s = cfg.ssm
            di = s.expand * cfg.d_model
            K = s.d_conv - 1
            if s.variant == "mamba1":
                return {"conv": mk(lead + (B, K, di), jnp.bfloat16),
                        "h": mk(lead + (B, di, s.d_state), jnp.float32)}
            return {"conv_x": mk(lead + (B, K, di), jnp.bfloat16),
                    "conv_bc": mk(lead + (B, K, 2 * s.d_state), jnp.bfloat16),
                    "h": mk(lead + (B, s.n_heads, di // s.n_heads, s.d_state),
                            jnp.float32)}

        if cfg.family == "ssm":
            return _ssm_state((L,))
        if cfg.family == "hybrid":
            g, tail = hyb.hybrid_split(cfg)
            k = cfg.hybrid_attn_every
            c = {"ssm": _ssm_state((g, k)),
                 "attn_k": mk((g, B, max_len, KVH, D), kvd),
                 "attn_v": mk((g, B, max_len, KVH, D), kvd)}
            if tail:
                c["tail"] = _ssm_state((tail,))
            return c
        raise ValueError(f"{cfg.family} has no decode cache (encoder-only?)")

    # ---------------------------- prefill ----------------------------- #
    def _pad_to(u, target_len, axis):
        pad = target_len - u.shape[axis]
        if pad <= 0:
            return u
        widths = [(0, 0)] * u.ndim
        widths[axis] = (0, pad)
        return jnp.pad(u, widths)

    def prefill(p, batch, max_len: int, ctx: ParallelContext = LOCAL_CTX):
        if cfg.is_encoder:
            raise ValueError("encoder-only model has no prefill/decode")
        x = _embed_in(p, batch)
        S = x.shape[1]
        if cfg.family == "ssm":
            x, states = hyb.ssm_stack_prefill(p["stack"], cfg, x,
                                              remat=cfg.remat_policy)
            cache = states
        elif cfg.family == "hybrid":
            x, st, kvs, tail = hyb.hybrid_stack_prefill(
                p["stack"], cfg, x, remat=cfg.remat_policy, ctx=ctx)
            cache = {"ssm": st,
                     "attn_k": _pad_to(kvs[0], max_len, 2),
                     "attn_v": _pad_to(kvs[1], max_len, 2)}
            if tail is not None:
                cache["tail"] = tail
        else:
            x, aux, kvs = _stack_fwd(p, x, batch, ctx, collect_kv=True)
            if cfg.family == "vlm":
                k, v = kvs
                xk, xv = tfm.vlm_precompute_cross_kv(
                    p["stack"], cfg, batch["vision"].astype(dtype))
                cache = {"k": _pad_to(k, max_len, 3),
                         "v": _pad_to(v, max_len, 3),
                         "cross_k": xk, "cross_v": xv}
            elif a.pattern == "local_global":
                lkv, gkv, tkv = kvs
                cache = {"local_k": lkv[0], "local_v": lkv[1],
                         "global_k": _pad_to(gkv[0], max_len, 2),
                         "global_v": _pad_to(gkv[1], max_len, 2)}
                if tkv is not None:
                    cache["tail_k"], cache["tail_v"] = tkv
            else:
                k, v = kvs
                cache = {"k": _pad_to(k, max_len, 2),
                         "v": _pad_to(v, max_len, 2)}
        x = rmsnorm(p["final_ln"], x[:, -1:], cfg.norm_eps)
        return unembed(p["embed"], x), cache

    # ------------------------- decode step ---------------------------- #
    def decode_step(p, tokens, cache, pos, ctx: ParallelContext = LOCAL_CTX):
        """tokens (B,1) int32; pos: scalar or (B,) absolute position."""
        x = embed(p["embed"], tokens, scale_by_dim=cfg.embed_scale)
        if cfg.family in ("dense", "moe") and a.pattern != "local_global":
            x, ck, cv = tfm.uniform_stack_decode(p["stack"], cfg, x,
                                                 cache["k"], cache["v"],
                                                 pos, ctx=ctx)
            cache = dict(cache, k=ck, v=cv)
        elif cfg.family in ("dense", "moe"):
            x, cache = tfm.lg_stack_decode(p["stack"], cfg, x, cache, pos,
                                           ctx=ctx)
        elif cfg.family == "vlm":
            x, cache = tfm.vlm_stack_decode(p["stack"], cfg, x, cache, pos,
                                            ctx=ctx)
        elif cfg.family == "ssm":
            x, cache = hyb.ssm_stack_decode(p["stack"], cfg, x, cache)
        elif cfg.family == "hybrid":
            x, st, ck, cv, tail = hyb.hybrid_stack_decode(
                p["stack"], cfg, x, cache["ssm"],
                cache["attn_k"], cache["attn_v"], cache.get("tail"),
                pos, ctx=ctx)
            cache = dict(cache, ssm=st, attn_k=ck, attn_v=cv)
            if tail is not None:
                cache = dict(cache, tail=tail)
        else:
            raise ValueError(cfg.family)
        x = rmsnorm(p["final_ln"], x, cfg.norm_eps)
        return unembed(p["embed"], x), cache

    return Model(cfg, init, loss_fn, forward, prefill, decode_step, init_cache)
