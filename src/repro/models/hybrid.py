"""SSM stacks (falcon-mamba) and hybrid stacks (zamba2: Mamba-2 backbone
with one SHARED transformer block applied after every k SSM layers).

Simplification vs. the zamba2 paper noted in DESIGN.md: the shared block
here consumes the running hidden state directly (zamba2 concatenates the
original embedding; we keep a single-width residual for scan uniformity).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.layers import Params, rmsnorm, rmsnorm_init
from repro.models.moe import LOCAL_CTX, ParallelContext
from repro.models.transformer import _remat, _stack_init, layer_decode, layer_fwd, layer_init

Cache = Dict[str, Any]


# --------------------------------------------------------------------- #
#  One SSM residual layer                                                #
# --------------------------------------------------------------------- #
def ssm_layer_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    init = ssm.mamba1_init if cfg.ssm.variant == "mamba1" else ssm.mamba2_init
    return {"ln": rmsnorm_init(cfg.d_model), "mixer": init(key, cfg, dtype)}


def ssm_layer_fwd(lp: Params, cfg: ModelConfig, x: jnp.ndarray,
                  ctx=None) -> jnp.ndarray:
    fwd = ssm.mamba1_forward if cfg.ssm.variant == "mamba1" else ssm.mamba2_forward
    return x + fwd(lp["mixer"], cfg, rmsnorm(lp["ln"], x, cfg.norm_eps),
                   ctx=ctx)


def ssm_layer_step(lp: Params, cfg: ModelConfig, x, state):
    step = ssm.mamba1_step if cfg.ssm.variant == "mamba1" else ssm.mamba2_step
    out, state = step(lp["mixer"], cfg, rmsnorm(lp["ln"], x, cfg.norm_eps), state)
    return x + out, state


def ssm_init_state(cfg: ModelConfig, batch: int):
    init = (ssm.mamba1_init_state if cfg.ssm.variant == "mamba1"
            else ssm.mamba2_init_state)
    return init(cfg, batch)


# ===================================================================== #
#  Pure SSM stack (falcon-mamba)                                         #
# ===================================================================== #
def ssm_stack_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    return _stack_init(lambda k: ssm_layer_init(k, cfg, dtype), key, cfg.n_layers)


def ssm_stack_fwd(sp: Params, cfg: ModelConfig, x, *, remat: str,
                  unroll: int = 1, ctx=None):
    def body(h, lp):
        return ssm_layer_fwd(lp, cfg, h, ctx=ctx), None

    x, _ = jax.lax.scan(_remat(body, remat), x, sp, unroll=unroll)
    return x, jnp.zeros((), jnp.float32), None


def ssm_stack_prefill(sp: Params, cfg: ModelConfig, x, *, remat: str):
    """Forward over the prompt, also returning final per-layer SSM states.

    (Exact-state prefill: we re-run the recurrences keeping final states.)
    """
    def body(h, lp):
        u = rmsnorm(lp["ln"], h, cfg.norm_eps)
        if cfg.ssm.variant == "mamba1":
            xx, z, dt, A, B, C = ssm._mamba1_inputs(lp["mixer"], cfg, u)
            y, state = ssm.mamba1_scan(xx, dt, A, B, C, cfg.ssm.chunk_size)
            y = y + lp["mixer"]["D"] * xx.astype(jnp.float32)
            y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
            out = jnp.einsum("bsc,cd->bsd", y, lp["mixer"]["out_proj"])
        else:
            out, state = _mamba2_fwd_with_state(lp["mixer"], cfg, u)
        return h + out, dict(h=state, **_conv_tail(cfg, u, lp["mixer"]))

    x, states = jax.lax.scan(body, x, sp)
    return x, states


def _conv_tail(cfg: ModelConfig, u: jnp.ndarray, mp: Params):
    """Last (d_conv - 1) pre-conv channel inputs, for decode warm-start."""
    K = cfg.ssm.d_conv
    x = jnp.einsum("bsd,de->bse", u[:, -(K - 1):], mp["in_x"])
    if cfg.ssm.variant == "mamba1":
        return {"conv": x.astype(jnp.bfloat16)}
    bc = jnp.einsum("bsd,de->bse", u[:, -(K - 1):], mp["in_bc"])
    return {"conv_x": x.astype(jnp.bfloat16),
            "conv_bc": bc.astype(jnp.bfloat16)}


def _mamba2_fwd_with_state(mp, cfg, u):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H, N = s.n_heads, s.d_state
    P = di // H
    z, x, B, C, dt = ssm._mamba2_project(mp, cfg, u)
    A = -jnp.exp(mp["A_log"])
    Bsz, S = u.shape[:2]
    y, hT = ssm.ssd_chunked(x.reshape(Bsz, S, H, P), dt, A, B, C, s.chunk_size)
    y = y + mp["D"][:, None] * x.reshape(Bsz, S, H, P).astype(jnp.float32)
    y = y.reshape(Bsz, S, di)
    y = rmsnorm(mp["norm"], (y * jax.nn.silu(z.astype(jnp.float32))
                             ).astype(u.dtype), cfg.norm_eps)
    return jnp.einsum("bsc,cd->bsd", y, mp["out_proj"]), hT


def ssm_stack_decode(sp: Params, cfg: ModelConfig, x, states, *, ctx=None):
    def body(h, inp):
        lp, st = inp
        h, st = ssm_layer_step(lp, cfg, h, st)
        return h, st

    x, states = jax.lax.scan(body, x, (sp, states))
    return x, states


# ===================================================================== #
#  Hybrid stack (zamba2): groups of k SSM layers + SHARED attn block     #
# ===================================================================== #
def hybrid_split(cfg: ModelConfig) -> Tuple[int, int]:
    k = cfg.hybrid_attn_every
    g = cfg.n_layers // k
    return g, cfg.n_layers - g * k


def hybrid_stack_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    g, tail = hybrid_split(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ssm": jax.vmap(lambda k: _stack_init(
            lambda kk: ssm_layer_init(kk, cfg, dtype), k, cfg.hybrid_attn_every))(
            jax.random.split(k1, g)),                      # (g, k, ...)
        "shared_attn": layer_init(k2, cfg, dtype),         # ONE shared block
        "tail": (_stack_init(lambda k: ssm_layer_init(k, cfg, dtype), k3, tail)
                 if tail else None),
    }


def hybrid_stack_fwd(sp: Params, cfg: ModelConfig, x, *, ctx, impl, chunk,
                     remat: str, unroll: int = 1, collect_kv: bool = False):
    def ssm_body(h, lp):
        return ssm_layer_fwd(lp, cfg, h, ctx=ctx), None

    def group_body(h, gp):
        h, _ = jax.lax.scan(ssm_body, h, gp)
        h, kv, _ = layer_fwd(sp["shared_attn"], cfg, h, kind="causal",
                             ctx=ctx, impl=impl, chunk=chunk,
                             return_kv=collect_kv)
        return h, kv

    x, kvs = jax.lax.scan(_remat(group_body, remat), x, sp["ssm"],
                          unroll=unroll)
    if sp.get("tail") is not None:
        x, _ = jax.lax.scan(_remat(ssm_body, remat), x, sp["tail"])
    return x, jnp.zeros((), jnp.float32), kvs   # kvs: (g, B, S, KVH, D)


def hybrid_stack_prefill(sp: Params, cfg: ModelConfig, x, *, remat: str,
                         ctx: ParallelContext = LOCAL_CTX, impl: str = "flashref",
                         chunk: int = 1024):
    def group_body(h, gp):
        def body(hh, lp):
            u = rmsnorm(lp["ln"], hh, cfg.norm_eps)
            out, state = _mamba2_fwd_with_state(lp["mixer"], cfg, u)
            return hh + out, dict(h=state, **_conv_tail(cfg, u, lp["mixer"]))

        h, states = jax.lax.scan(body, h, gp)
        h, kv, _ = layer_fwd(sp["shared_attn"], cfg, h, kind="causal",
                             ctx=ctx, impl=impl, chunk=chunk,
                             return_kv=True)
        return h, (states, kv)

    x, (states, kvs) = jax.lax.scan(group_body, x, sp["ssm"])
    tail_states = None
    if sp.get("tail") is not None:
        def body(hh, lp):
            u = rmsnorm(lp["ln"], hh, cfg.norm_eps)
            out, state = _mamba2_fwd_with_state(lp["mixer"], cfg, u)
            return hh + out, dict(h=state, **_conv_tail(cfg, u, lp["mixer"]))

        x, tail_states = jax.lax.scan(body, x, sp["tail"])
    return x, states, kvs, tail_states


def hybrid_stack_decode(sp: Params, cfg: ModelConfig, x, states, cache_k,
                        cache_v, tail_states, pos, *, ctx):
    def ssm_body(h, inp):
        lp, st = inp
        h, st = ssm_layer_step(lp, cfg, h, st)
        return h, st

    def group_body(h, inp):
        gp, st, ck, cv = inp
        h, st = jax.lax.scan(ssm_body, h, (gp, st))
        h, ck, cv = layer_decode(sp["shared_attn"], cfg, h, ck, cv, pos,
                                 kind="causal", ctx=ctx)
        return h, (st, ck, cv)

    x, (states, cache_k, cache_v) = jax.lax.scan(
        group_body, x, (sp["ssm"], states, cache_k, cache_v))
    if sp.get("tail") is not None:
        x, tail_states = jax.lax.scan(ssm_body, x, (sp["tail"], tail_states))
    return x, states, cache_k, cache_v, tail_states
