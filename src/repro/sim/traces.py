"""Deterministic multi-tenant request-trace generation.

A trace is the simulator's *input tape*: tenant arrivals/departures
(fleet events), per-tenant request streams (serving events), and
optional injected faults — all as ``repro.ft.inject.InjectEvent``s on a
virtual-time axis, so one sorted event list drives both the fleet event
loop and the request-serving loop.

Every stochastic choice flows through ONE explicit
``numpy.random.Generator`` in a fixed loop order — no module-level RNG
anywhere — so the same seed reproduces the same tenants, the same
request timestamps, and (through the deterministic fleet replay) the
same simulated metrics bit-for-bit.

Trace shape
  * **tenants** — ``n_tenants`` long-lived services, each an
    interference ``WorkloadProfile`` derived from a model config drawn
    from the family registry (``repro.configs.registry``): the config's
    family picks the resource-axis mix (dense/moe decode is
    bandwidth-bound, ssm scan leans on vpu/smem, vision/speech encoders
    on mxu), the tenant's intensity scales it.  A ``slo_fraction`` of
    tenants are SLO class (tight ``slo_slowdown``, a per-token latency
    target); the rest are best-effort.
  * **arrivals** — a configurable fraction lands in a same-tick storm at
    t=0 (exercising the fleet's batched admission); the rest ramp in.
    Best-effort tenants churn: a ``churn_fraction`` departs mid-trace
    and is replaced by a fresh tenant.
  * **requests** — per-tenant non-homogeneous Poisson arrivals
    (thinning) with rate ``base_rate x day-curve x burst``: a sinusoidal
    diurnal curve (per-tenant phase) and fleet-wide burst-storm windows
    that multiply every tenant's rate.  Request sizes are
    exponential-tailed token counts.
  * **faults** — device kills / stragglers at scripted times, reusing
    the ``repro.ft.inject`` event vocabulary verbatim.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.registry import ASSIGNED, PAPER_WORKLOADS
from repro.core.fleet import BEST_EFFORT, SLO
from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import RESOURCE_AXES, TPU_V5E, DeviceModel
from repro.ft.inject import InjectEvent, arrive, depart, kill, slow

# resource-axis mix per model family (fraction of the tenant's intensity
# landing on each axis): decode serving of dense/moe decoders is
# bandwidth-bound (weight + kv streaming), ssm scans lean on vector +
# scratch, vision/speech encoders are matmul-heavy — the paper's point
# that "GPU util" hides exactly these differences.
FAMILY_AXIS_MIX: Dict[str, Dict[str, float]] = {
    "dense":  dict(mxu=0.50, vpu=0.10, issue=0.12, smem=0.06,
                   hbm=1.00, l2=1.00),
    "moe":    dict(mxu=0.35, vpu=0.10, issue=0.10, smem=0.05,
                   hbm=1.00, l2=0.90),
    "ssm":    dict(mxu=0.25, vpu=0.90, issue=0.50, smem=0.30,
                   hbm=0.60, l2=0.60),
    "hybrid": dict(mxu=0.40, vpu=0.55, issue=0.30, smem=0.18,
                   hbm=0.85, l2=0.85),
    "vlm":    dict(mxu=1.00, vpu=0.15, issue=0.30, smem=0.30,
                   hbm=0.50, l2=0.50),
    "audio":  dict(mxu=0.85, vpu=0.40, issue=0.30, smem=0.20,
                   hbm=0.60, l2=0.60),
}


def request(t: float, tenant: str, req_id: int, n_tokens: int) -> InjectEvent:
    """One serving request: ``n_tokens`` of decode for ``tenant``.  The
    fleet event loop ignores these; the simulator serves them."""
    return InjectEvent(t, "request", {"tenant": tenant, "req_id": req_id,
                                      "n_tokens": int(n_tokens)})


def profile_shift(t: float, tenant: str, demand_scale: float) -> InjectEvent:
    """Mid-trace calibration drift: from ``t`` on, ``tenant``'s TRUE
    resource demand is its profile's scaled by ``demand_scale`` while
    the fleet keeps believing the original — the drift monitor's job
    (``repro.calib.drift``) is to notice and trigger a re-fit."""
    return InjectEvent(t, "profile-shift",
                       {"tenant": tenant, "demand_scale": float(demand_scale)})


@dataclass(frozen=True)
class TraceConfig:
    """Knobs of one generated trace (all stochastic draws come from the
    explicit Generator passed to ``generate_trace``; ``seed`` only names
    the default one)."""
    seed: int = 0
    duration: float = 300.0          # virtual seconds of request traffic
    n_tenants: int = 32
    slo_fraction: float = 0.5        # fraction of tenants in the SLO class
    storm_fraction: float = 0.5      # tenants arriving in the t=0 storm
    arrival_ramp: float = 8.0        # the rest arrive over (0, ramp]
    base_rate: float = 0.30          # requests/s/tenant at day-curve mean
    diurnal_amplitude: float = 0.6   # day-curve swing (+-)
    diurnal_period: float = 120.0    # virtual seconds per "day"
    n_bursts: int = 3                # fleet-wide burst-storm windows
    burst_factor: float = 4.0        # rate multiplier inside a burst
    burst_duration: float = 6.0
    churn_fraction: float = 0.25     # of best-effort tenants depart+replace
    min_tokens: int = 8
    mean_tokens: float = 48.0
    max_tokens: int = 256
    time_scale: float = 0.002        # profile step-time -> virtual s/token
    slo_queue_margin: float = 2.0    # per-token SLO headroom over the
                                     # interference SLO
    queue_slack: float = 4.0         # additive first-token slack (s): the
                                     # TTFT half of the TTFT+TBT deadline,
                                     # covering scheduling/queueing delay
    kills: Tuple[Tuple[float, str], ...] = ()    # (t, device_id)
    slows: Tuple[Tuple[float, str], ...] = ()    # (t, device_id)
    # (t, tenant, demand_scale): the tenant's true demand shifts while
    # the fleet's belief stays — exercises the calib drift monitor
    profile_shifts: Tuple[Tuple[float, str, float], ...] = ()


@dataclass(frozen=True)
class TenantSpec:
    """One tenant: its fleet-side interference profile plus the serving-
    side request-latency model derived from it."""
    name: str
    arch: str                        # registry config the tenant runs
    family: str
    priority: str                    # SLO | BEST_EFFORT
    profile: WorkloadProfile
    tbt_base: float                  # isolated virtual seconds per token
    tbt_slo: float                   # per-token deadline contribution
    arrival: float
    depart: Optional[float] = None   # churn departure (best-effort only)


@dataclass
class Trace:
    """A replayable trace: feed ``events`` to the simulator (or any
    ``FaultInjector``-style loop) as many times as you like."""
    config: TraceConfig
    tenants: Dict[str, TenantSpec]
    events: List[InjectEvent] = field(default_factory=list)

    @property
    def duration(self) -> float:
        return self.config.duration

    @property
    def n_requests(self) -> int:
        return sum(1 for e in self.events if e.kind == "request")

    def requests_of(self, tenant: str) -> List[InjectEvent]:
        return [e for e in self.events
                if e.kind == "request" and e.payload["tenant"] == tenant]

    def tenants_of(self, priority: str) -> List[TenantSpec]:
        return [t for t in self.tenants.values() if t.priority == priority]

    def summary(self) -> Dict:
        kinds: Dict[str, int] = {}
        for e in self.events:
            kinds[e.kind] = kinds.get(e.kind, 0) + 1
        return {
            "seed": self.config.seed,
            "duration": self.config.duration,
            "tenants": len(self.tenants),
            "slo_tenants": len(self.tenants_of(SLO)),
            "requests": self.n_requests,
            "events": kinds,
        }


# ------------------------------------------------------------------ #
#  Tenant profile synthesis                                            #
# ------------------------------------------------------------------ #
def tenant_profile(rng: np.random.Generator, name: str, arch,
                   dev: DeviceModel, priority: str) -> WorkloadProfile:
    """Interference profile of one tenant's resident serving instance.

    The config's family selects the axis mix; the tenant's intensity
    (peak utilization of its bottleneck axis) and SLO tightness are
    drawn from ``rng``.  Built like the bench mixes: demand is expressed
    as fraction-of-capacity x step duration with the duration as the
    latency floor, so per-axis utilization equals the mix fraction.
    """
    mix = FAMILY_AXIS_MIX[arch.family]
    if priority == SLO:
        u = float(rng.uniform(0.30, 0.55))
        slo = float(rng.uniform(1.2, 1.5))
    else:
        u = float(rng.uniform(0.12, 0.40))
        slo = float(rng.uniform(6.0, 14.0))
    # larger active-parameter counts -> longer per-token step
    step = 0.6 + 0.15 * math.log10(max(arch.n_active_params(), 1e6) / 1e6)
    demand = {r: mix.get(r, 0.0) * u * dev.capacity(r) * step
              for r in RESOURCE_AXES}
    kern = KernelProfile(f"{name}#step", demand=demand, duration=step)
    return WorkloadProfile(name, (kern,), slo_slowdown=slo)


def _make_tenant(rng: np.random.Generator, name: str, archs, cfg: TraceConfig,
                 dev: DeviceModel, priority: str, arrival: float,
                 departs: Optional[float] = None) -> TenantSpec:
    arch = archs[int(rng.integers(len(archs)))]
    prof = tenant_profile(rng, name, arch, dev, priority)
    tbt_base = prof.total_time(dev) * cfg.time_scale
    tbt_slo = tbt_base * prof.slo_slowdown * cfg.slo_queue_margin
    return TenantSpec(name, arch.name, arch.family, priority, prof,
                      tbt_base, tbt_slo, arrival, departs)


# ------------------------------------------------------------------ #
#  Request arrivals: non-homogeneous Poisson via thinning              #
# ------------------------------------------------------------------ #
def _burst_windows(rng: np.random.Generator, cfg: TraceConfig
                   ) -> List[Tuple[float, float]]:
    if cfg.n_bursts <= 0 or cfg.duration <= 0:
        return []
    starts = np.sort(rng.uniform(0.1 * cfg.duration, 0.9 * cfg.duration,
                                 size=cfg.n_bursts))
    return [(float(s), float(min(s + cfg.burst_duration, cfg.duration)))
            for s in starts]


def _rate(cfg: TraceConfig, t: float, phase: float,
          bursts: List[Tuple[float, float]]) -> float:
    day = 1.0 + cfg.diurnal_amplitude * math.sin(
        2.0 * math.pi * t / cfg.diurnal_period + phase)
    boost = cfg.burst_factor if any(a <= t < b for a, b in bursts) else 1.0
    return cfg.base_rate * max(day, 0.0) * boost


def _sample_requests(rng: np.random.Generator, cfg: TraceConfig,
                     tenant: TenantSpec, phase: float,
                     bursts: List[Tuple[float, float]],
                     next_id: int) -> List[InjectEvent]:
    lam_max = (cfg.base_rate * (1.0 + cfg.diurnal_amplitude)
               * cfg.burst_factor)
    t0 = tenant.arrival
    t1 = tenant.depart if tenant.depart is not None else cfg.duration
    out: List[InjectEvent] = []
    t = t0
    while True:
        t += float(rng.exponential(1.0 / max(lam_max, 1e-9)))
        if t >= t1:
            break
        if rng.random() < _rate(cfg, t, phase, bursts) / lam_max:
            n_tok = int(min(cfg.max_tokens, cfg.min_tokens
                            + rng.exponential(max(cfg.mean_tokens
                                                  - cfg.min_tokens, 1.0))))
            out.append(request(t, tenant.name, next_id + len(out), n_tok))
    return out


# ------------------------------------------------------------------ #
#  The generator                                                       #
# ------------------------------------------------------------------ #
def generate_trace(cfg: TraceConfig,
                   rng: Optional[np.random.Generator] = None,
                   dev: DeviceModel = TPU_V5E) -> Trace:
    """Generate one replayable trace.  All sampling goes through ``rng``
    (default: ``np.random.default_rng(cfg.seed)``) in a fixed loop
    order, so equal seeds give bit-identical traces."""
    rng = rng if rng is not None else np.random.default_rng(cfg.seed)
    archs = list(ASSIGNED) + list(PAPER_WORKLOADS)

    n_slo = int(round(cfg.n_tenants * cfg.slo_fraction))
    classes = [SLO] * n_slo + [BEST_EFFORT] * (cfg.n_tenants - n_slo)
    classes = [classes[i] for i in rng.permutation(cfg.n_tenants)]
    n_storm = int(round(cfg.n_tenants * cfg.storm_fraction))

    tenants: Dict[str, TenantSpec] = {}
    events: List[InjectEvent] = []
    for i, prio in enumerate(classes):
        t_arr = (0.0 if i < n_storm
                 else float(rng.uniform(0.0, cfg.arrival_ramp)))
        spec = _make_tenant(rng, f"tenant{i:03d}", archs, cfg, dev,
                            prio, t_arr)
        tenants[spec.name] = spec
        events.append(arrive(spec.arrival, spec.profile,
                             priority=spec.priority))

    # churn: a fraction of best-effort tenants departs mid-trace and is
    # replaced by a fresh best-effort tenant shortly after
    be = [t for t in tenants.values() if t.priority == BEST_EFFORT]
    n_churn = int(round(len(be) * cfg.churn_fraction))
    churners = [be[i] for i in rng.permutation(len(be))[:n_churn]]
    for j, old in enumerate(churners):
        t_dep = float(rng.uniform(0.35, 0.70)) * cfg.duration
        tenants[old.name] = TenantSpec(
            old.name, old.arch, old.family, old.priority, old.profile,
            old.tbt_base, old.tbt_slo, old.arrival, depart=t_dep)
        events.append(depart(t_dep, old.name))
        t_new = min(t_dep + float(rng.uniform(2.0, 10.0)),
                    cfg.duration - 1.0)
        repl = _make_tenant(rng, f"tenant{cfg.n_tenants + j:03d}", archs,
                            cfg, dev, BEST_EFFORT, t_new)
        tenants[repl.name] = repl
        events.append(arrive(repl.arrival, repl.profile,
                             priority=repl.priority))

    bursts = _burst_windows(rng, cfg)
    next_id = 0
    for spec in tenants.values():
        phase = float(rng.uniform(0.0, 2.0 * math.pi))
        reqs = _sample_requests(rng, cfg, spec, phase, bursts, next_id)
        next_id += len(reqs)
        events.extend(reqs)

    for t, device in cfg.kills:
        events.append(kill(float(t), device))
    for t, device in cfg.slows:
        events.append(slow(float(t), device))
    for t, tenant, scale in cfg.profile_shifts:
        events.append(profile_shift(float(t), tenant, scale))
    return Trace(cfg, tenants, events)
