"""Virtual-clock closed-loop serving simulator over the FleetScheduler.

The missing piece between "cold plans match oracles" and "the system
holds SLOs in production": a trace (`repro.sim.traces`) drives the
fleet's event loop AND a per-tenant request-serving loop on one shared
virtual clock (``repro.ft.inject.FakeClock``), so sustained multi-tenant
load, arrival storms, churn, and mid-trace faults all exercise the
scheduler exactly as scripted — deterministically.

Each tick (reusing the ``FaultInjector`` event loop):
  1. due trace events apply — tenant arrivals admit through the fleet
     (same-tick storms through one batched ``submit_many`` replay),
     departures cancel outstanding requests and remove the tenant,
     requests enqueue, kills stop a device's heartbeats, stragglers
     feed its monitor;
  2. live devices heartbeat and ``fleet.tick()`` runs (failure
     detection, retries, replanning);
  3. the serving pass: every PLACED tenant drains its FIFO request
     queue at its interference-inflated rate — per-token time =
     ``tbt_base x predicted_slowdown``, where the slowdown is the fleet
     placement's estimator prediction (computed by ``solve_scenarios``
     through the fleet's group pricing).  Unplaced tenants (queued,
     displaced by a failure, degraded) serve nothing — their requests
     age toward their deadlines, which is exactly how scheduler
     decisions become SLO attainment.

The simulator never touches wall time or module-level RNG: a trace +
seed reproduces the same report bit-for-bit (the CI determinism gate in
``benchmarks/bench_trace.py``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.fleet import FleetConfig, FleetScheduler
from repro.core.resources import DeviceModel
from repro.ft.inject import FakeClock, FaultInjector, InjectEvent
from repro.sim.metrics import RequestRecord, compute_report
from repro.sim.traces import Trace


@dataclass(frozen=True)
class SimConfig:
    """Simulator knobs (fleet knobs live in ``FleetConfig``)."""
    tick_dt: float = 0.5             # virtual seconds per event-loop tick
    settle: float = 30.0             # drain time after the last event


def default_fleet_config() -> FleetConfig:
    """The simulator's default fleet posture: k=3 colocation, fast
    failure detection on the virtual clock, 1s retry backoff."""
    return FleetConfig(max_group_size=3, heartbeat_timeout=3.0,
                       backoff_base=1.0, queue_limit=64)


class _TraceInjector(FaultInjector):
    """FaultInjector that also understands serving-trace events:
    ``request`` enqueues into the simulator; ``depart`` cancels the
    tenant's outstanding requests before removing it from the fleet
    (and tolerates tenants the fleet rejected at admission)."""

    def __init__(self, sim: "Simulator"):
        super().__init__(sim.fleet, sim.clock, tick_dt=sim.scfg.tick_dt,
                         on_tick=sim._on_tick)
        self.sim = sim

    def _apply(self, ev: InjectEvent) -> None:
        if ev.kind == "request":
            self.sim._enqueue(ev)
            self.applied.append(ev)
        elif ev.kind == "depart":
            self.sim._depart(ev.payload["name"])
            self.applied.append(ev)
        else:
            super()._apply(ev)


class Simulator:
    """Run one trace against one fleet; ``run()`` returns the report.

    >>> trace = generate_trace(TraceConfig(seed=0, kills=((120, "dev2"),)))
    >>> sim = Simulator(trace, {f"dev{i}": TPU_V5E for i in range(12)})
    >>> report = sim.run()
    >>> report["slo"]["per_class"]["slo"]["attainment"]
    """

    def __init__(self, trace: Trace,
                 devices: Mapping[str, DeviceModel],
                 fleet_config: Optional[FleetConfig] = None,
                 sim_config: Optional[SimConfig] = None):
        self.trace = trace
        self.scfg = sim_config or SimConfig()
        self.clock = FakeClock()
        self.fleet = FleetScheduler(dict(devices),
                                    fleet_config or default_fleet_config(),
                                    clock=self.clock)
        self.records: List[RequestRecord] = []
        self.queues: Dict[str, Deque[RequestRecord]] = {}
        self.busy: Dict[str, float] = {}
        self.resident_time: Dict[str, float] = {}
        self.gain_samples: List[float] = []
        self.report: Optional[Dict] = None
        self._plan = None
        self._plan_rev = -1
        self._loc: Dict[str, Tuple[str, float]] = {}

    # ------------------------- event handlers --------------------- #
    def _enqueue(self, ev: InjectEvent) -> None:
        p = ev.payload
        spec = self.trace.tenants.get(p["tenant"])
        if spec is None:
            raise KeyError(f"request for unknown tenant {p['tenant']!r} "
                           "(broken trace)")
        rec = RequestRecord(
            tenant=spec.name, req_id=int(p["req_id"]), arrival=ev.t,
            n_tokens=int(p["n_tokens"]), priority=spec.priority,
            tbt_slo=spec.tbt_slo, slack=self.trace.config.queue_slack,
            remaining=float(p["n_tokens"]))
        self.records.append(rec)
        if spec.name in self.fleet:
            self.queues.setdefault(spec.name, deque()).append(rec)
        else:
            # tenant was rejected at admission (or already departed):
            # the request is canceled, not an SLO miss
            rec.canceled = True

    def _depart(self, name: str) -> None:
        for rec in self.queues.pop(name, ()):  # cancel outstanding work
            rec.canceled = True
        if name in self.fleet:
            self.fleet.remove(name)

    # --------------------------- serving -------------------------- #
    def _refresh_plan(self) -> None:
        rev = self.fleet.stats["replans"]
        if self._plan is not None and rev == self._plan_rev:
            return
        self._plan = self.fleet.plan()
        self._plan_rev = rev
        self._loc = {}
        for did, p in self._plan.placements.items():
            for n in p.workloads:
                self._loc[n] = (did, float(p.predicted_slowdown.get(n, 1.0)))

    def _on_tick(self, fleet: FleetScheduler, now: float) -> None:
        """One serving pass over [now, now + tick_dt): every placed
        tenant drains its queue at its interference-inflated rate."""
        self._refresh_plan()
        dt = self.scfg.tick_dt
        for did, p in self._plan.placements.items():
            self.resident_time[did] = (self.resident_time.get(did, 0.0)
                                       + dt * len(p.workloads))
        gains = [p.throughput_gain
                 for p in self._plan.placements.values() if p.workloads]
        if gains:
            self.gain_samples.append(float(np.mean(gains)))

        for tenant, q in self.queues.items():
            if not q:
                continue
            loc = self._loc.get(tenant)
            if loc is None:
                continue               # unplaced: requests age, unserved
            did, slowdown = loc
            spec = self.trace.tenants[tenant]
            tbt_eff = spec.tbt_base * max(slowdown, 1.0)
            budget = dt
            while q and budget > 1e-12:
                rec = q[0]
                if rec.start is None:
                    rec.start = now + (dt - budget)
                take = min(rec.remaining * tbt_eff, budget)
                rec.remaining -= take / tbt_eff
                rec.service += take
                budget -= take
                if rec.remaining <= 1e-9:
                    rec.finish = now + (dt - budget)
                    q.popleft()
            self.busy[did] = self.busy.get(did, 0.0) + (dt - budget)

    # ----------------------------- run ----------------------------- #
    def run(self) -> Dict:
        """Replay the whole trace (plus settle time) and fold the
        records into the metrics report."""
        injector = _TraceInjector(self)
        injector.run(self.trace.events,
                     until=self.trace.duration + self.scfg.settle)
        self.report = compute_report(
            self.trace, self.records, self.fleet, self.clock(),
            self.busy, self.resident_time, self.gain_samples)
        return self.report
