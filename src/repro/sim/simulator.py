"""Virtual-clock closed-loop serving simulator over the FleetScheduler.

The missing piece between "cold plans match oracles" and "the system
holds SLOs in production": a trace (`repro.sim.traces`) drives the
fleet's event loop AND a per-tenant request-serving loop on one shared
virtual clock (``repro.ft.inject.FakeClock``), so sustained multi-tenant
load, arrival storms, churn, and mid-trace faults all exercise the
scheduler exactly as scripted — deterministically.

Each tick (reusing the ``FaultInjector`` event loop):
  1. due trace events apply — tenant arrivals admit through the fleet
     (same-tick storms through one batched ``submit_many`` replay),
     departures cancel outstanding requests and remove the tenant,
     requests enqueue, kills stop a device's heartbeats, stragglers
     feed its monitor;
  2. live devices heartbeat and ``fleet.tick()`` runs (failure
     detection, retries, replanning);
  3. the serving pass: every PLACED tenant drains its FIFO request
     queue at its interference-inflated rate — per-token time =
     ``tbt_base x predicted_slowdown``, where the slowdown is the fleet
     placement's estimator prediction (computed by ``solve_scenarios``
     through the fleet's group pricing).  Unplaced tenants (queued,
     displaced by a failure, degraded) serve nothing — their requests
     age toward their deadlines, which is exactly how scheduler
     decisions become SLO attainment.

The simulator never touches wall time or module-level RNG: a trace +
seed reproduces the same report bit-for-bit (the CI determinism gate in
``benchmarks/bench_trace.py``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from repro.calib.drift import DriftConfig, DriftMonitor, scale_workload
from repro.core.fleet import FleetConfig, FleetScheduler
from repro.core.fracsearch import member_slowdowns
from repro.core.profile import WorkloadProfile
from repro.core.resources import DeviceModel
from repro.core.scenario import group_victim_scenarios
from repro.core.estimator import solve_scenarios
from repro.ft.inject import FakeClock, FaultInjector, InjectEvent
from repro.sim.metrics import RequestRecord, compute_report
from repro.sim.traces import Trace


@dataclass(frozen=True)
class SimConfig:
    """Simulator knobs (fleet knobs live in ``FleetConfig``)."""
    tick_dt: float = 0.5             # virtual seconds per event-loop tick
    settle: float = 30.0             # drain time after the last event
    calibrate: bool = True           # attach a repro.calib DriftMonitor
    refit: bool = True               # re-fit tenants the monitor flags
    drift: Optional[DriftConfig] = None   # monitor knobs (None = defaults)


def default_fleet_config() -> FleetConfig:
    """The simulator's default fleet posture: k=3 colocation, fast
    failure detection on the virtual clock, 1s retry backoff."""
    return FleetConfig(max_group_size=3, heartbeat_timeout=3.0,
                       backoff_base=1.0, queue_limit=64)


class _TraceInjector(FaultInjector):
    """FaultInjector that also understands serving-trace events:
    ``request`` enqueues into the simulator; ``depart`` cancels the
    tenant's outstanding requests before removing it from the fleet
    (and tolerates tenants the fleet rejected at admission)."""

    def __init__(self, sim: "Simulator"):
        super().__init__(sim.fleet, sim.clock, tick_dt=sim.scfg.tick_dt,
                         on_tick=sim._on_tick)
        self.sim = sim

    def _apply(self, ev: InjectEvent) -> None:
        if ev.kind == "request":
            self.sim._enqueue(ev)
            self.applied.append(ev)
        elif ev.kind == "depart":
            self.sim._depart(ev.payload["name"])
            self.applied.append(ev)
        elif ev.kind == "profile-shift":
            self.sim._shift(ev.payload["tenant"],
                            ev.payload["demand_scale"])
            self.applied.append(ev)
        else:
            super()._apply(ev)


class Simulator:
    """Run one trace against one fleet; ``run()`` returns the report.

    >>> trace = generate_trace(TraceConfig(seed=0, kills=((120, "dev2"),)))
    >>> sim = Simulator(trace, {f"dev{i}": TPU_V5E for i in range(12)})
    >>> report = sim.run()
    >>> report["slo"]["per_class"]["slo"]["attainment"]
    """

    def __init__(self, trace: Trace,
                 devices: Mapping[str, DeviceModel],
                 fleet_config: Optional[FleetConfig] = None,
                 sim_config: Optional[SimConfig] = None):
        self.trace = trace
        self.scfg = sim_config or SimConfig()
        self.clock = FakeClock()
        self.fleet = FleetScheduler(dict(devices),
                                    fleet_config or default_fleet_config(),
                                    clock=self.clock)
        self.records: List[RequestRecord] = []
        self.queues: Dict[str, Deque[RequestRecord]] = {}
        self.busy: Dict[str, float] = {}
        self.resident_time: Dict[str, float] = {}
        self.gain_samples: List[float] = []
        self.report: Optional[Dict] = None
        self._plan = None
        self._plan_rev = -1
        self._loc: Dict[str, Tuple[str, float]] = {}
        # calibration: the tenant's TRUE profile where it diverged from
        # the fleet's belief (profile-shift events), and the observed
        # serving slowdown each tick (== predicted while beliefs hold)
        self.true_profiles: Dict[str, WorkloadProfile] = {}
        self._obs_serve: Dict[str, float] = {}
        if self.scfg.calibrate:
            self.fleet.attach_calibration(
                DriftMonitor(self.scfg.drift or DriftConfig()))

    # ------------------------- event handlers --------------------- #
    def _enqueue(self, ev: InjectEvent) -> None:
        p = ev.payload
        spec = self.trace.tenants.get(p["tenant"])
        if spec is None:
            raise KeyError(f"request for unknown tenant {p['tenant']!r} "
                           "(broken trace)")
        rec = RequestRecord(
            tenant=spec.name, req_id=int(p["req_id"]), arrival=ev.t,
            n_tokens=int(p["n_tokens"]), priority=spec.priority,
            tbt_slo=spec.tbt_slo, slack=self.trace.config.queue_slack,
            remaining=float(p["n_tokens"]))
        self.records.append(rec)
        if spec.name in self.fleet:
            self.queues.setdefault(spec.name, deque()).append(rec)
        else:
            # tenant was rejected at admission (or already departed):
            # the request is canceled, not an SLO miss
            rec.canceled = True

    def _depart(self, name: str) -> None:
        for rec in self.queues.pop(name, ()):  # cancel outstanding work
            rec.canceled = True
        self.true_profiles.pop(name, None)
        self._obs_serve.pop(name, None)
        if name in self.fleet:
            self.fleet.remove(name)

    def _shift(self, name: str, scale: float) -> None:
        spec = self.trace.tenants.get(name)
        if spec is None:
            raise KeyError(f"profile-shift for unknown tenant {name!r} "
                           "(broken trace)")
        base = self.true_profiles.get(name, spec.profile)
        self.true_profiles[name] = scale_workload(base, float(scale))

    # --------------------------- serving -------------------------- #
    def _refresh_plan(self) -> None:
        rev = self.fleet.stats["replans"]
        if self._plan is not None and rev == self._plan_rev:
            return
        self._plan = self.fleet.plan()
        self._plan_rev = rev
        self._loc = {}
        for did, p in self._plan.placements.items():
            for n in p.workloads:
                self._loc[n] = (did, float(p.predicted_slowdown.get(n, 1.0)))

    # ------------------------- calibration ------------------------ #
    def _observe_drift(self) -> None:
        """Per-tick predicted-vs-observed pass over every placed tenant.

        Groups whose members all match the fleet's beliefs observe
        ``observed == predicted`` exactly (no solve — the plan and the
        fleet read the same group price), so a clean trace provably
        produces zero flags.  A group holding a shifted tenant is
        re-solved with TRUE profiles (same ``group_victim_scenarios`` /
        ``member_slowdowns`` fold the fleet prices with) and every
        member's observed slowdown is rebased to the fleet's believed
        baseline before it reaches the monitor.  Newly flagged tenants
        re-fit immediately (``SimConfig.refit``) — the resubmit replans,
        and the next tick serves from the corrected plan."""
        flagged: List[str] = []
        for did, p in self._plan.placements.items():
            if not any(n in self.true_profiles for n in p.workloads):
                for n in p.workloads:
                    pred = float(p.predicted_slowdown.get(n, 1.0))
                    self._obs_serve[n] = pred
                    if self.fleet.observe_slowdown(n, pred):
                        flagged.append(n)
                continue
            model = self.fleet.devices[did].model
            members = []
            for n in p.workloads:
                spec = self.trace.tenants.get(n)
                members.append(self.true_profiles.get(
                    n, spec.profile if spec is not None
                    else self.fleet.profile_of(n)))
            reps = {w.name: w.representative_kernel(model)
                    for w in members}
            frac = p.slot_fraction or None
            br = solve_scenarios(
                group_victim_scenarios(members, reps, frac), model)
            slows = member_slowdowns(members, model, br.slowdowns[:, 0])
            for n, true_w in zip(p.workloads, members):
                spec = self.trace.tenants.get(n)
                t_true = true_w.total_time(model)
                believed = self.fleet.profile_of(n)
                # the monitor compares against the fleet's predicted
                # slowdown, which is relative to the believed isolated
                # time; serving compares against the tenant's original
                # tbt_base — rebase to each baseline
                obs_fleet = slows[n] * t_true / max(
                    believed.total_time(model), 1e-12)
                t_spec = (spec.profile.total_time(model)
                          if spec is not None else t_true)
                self._obs_serve[n] = slows[n] * t_true / max(t_spec, 1e-12)
                if self.fleet.observe_slowdown(n, obs_fleet):
                    flagged.append(n)
        if self.scfg.refit:
            for n in flagged:
                self.fleet.refit_workload(n)

    def _on_tick(self, fleet: FleetScheduler, now: float) -> None:
        """One serving pass over [now, now + tick_dt): every placed
        tenant drains its queue at its interference-inflated rate."""
        self._refresh_plan()
        if self.fleet.calib is not None:
            self._observe_drift()
            self._refresh_plan()       # a refit replans mid-tick
        dt = self.scfg.tick_dt
        for did, p in self._plan.placements.items():
            self.resident_time[did] = (self.resident_time.get(did, 0.0)
                                       + dt * len(p.workloads))
        gains = [p.throughput_gain
                 for p in self._plan.placements.values() if p.workloads]
        if gains:
            self.gain_samples.append(float(np.mean(gains)))

        for tenant, q in self.queues.items():
            if not q:
                continue
            loc = self._loc.get(tenant)
            if loc is None:
                continue               # unplaced: requests age, unserved
            did, slowdown = loc
            spec = self.trace.tenants[tenant]
            # serve at the OBSERVED rate when calibrating (diverges from
            # predicted only for shifted tenants' groups)
            slowdown = self._obs_serve.get(tenant, slowdown)
            tbt_eff = spec.tbt_base * max(slowdown, 1.0)
            budget = dt
            while q and budget > 1e-12:
                rec = q[0]
                if rec.start is None:
                    rec.start = now + (dt - budget)
                take = min(rec.remaining * tbt_eff, budget)
                rec.remaining -= take / tbt_eff
                rec.service += take
                budget -= take
                if rec.remaining <= 1e-9:
                    rec.finish = now + (dt - budget)
                    q.popleft()
            self.busy[did] = self.busy.get(did, 0.0) + (dt - budget)

    # ----------------------------- run ----------------------------- #
    def run(self) -> Dict:
        """Replay the whole trace (plus settle time) and fold the
        records into the metrics report."""
        injector = _TraceInjector(self)
        injector.run(self.trace.events,
                     until=self.trace.duration + self.scfg.settle)
        self.report = compute_report(
            self.trace, self.records, self.fleet, self.clock(),
            self.busy, self.resident_time, self.gain_samples)
        return self.report
