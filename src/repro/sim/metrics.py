"""Per-request / per-tenant serving metrics for the trace simulator.

The SLO-attainment and tail-latency vocabulary of "ML Inference
Scheduling with Predictable Latency" (PAPERS.md), applied to the
simulator's request records:

  * a request's **deadline** is ``arrival + slack + n_tokens * tbt_slo``
    — the TTFT+TBT decomposition: an additive first-token slack (absorbs
    scheduling/queueing delay up to the trace's ``queue_slack``) plus
    the tenant's per-token latency target (its interference SLO times a
    headroom margin) scaled by the request length;
  * **SLO attainment** is the fraction of a tenant class's *resolved*
    requests (completed, or still unfinished past their deadline) that
    met their deadline — canceled requests (tenant departed or was
    rejected at admission) and still-censored requests are excluded;
  * **TBT** (time between tokens) is reported two ways: *service* TBT
    (interference-inflated execution only — what `solve_scenarios`
    predicts) and *observed* TBT (end-to-end latency / tokens, queueing
    and outage included); p50/p99 over completed requests;
  * **goodput** counts only tokens of SLO-met requests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional

import numpy as np


@dataclass
class RequestRecord:
    """One request's lifecycle inside the simulator."""
    tenant: str
    req_id: int
    arrival: float
    n_tokens: int
    priority: str
    tbt_slo: float
    slack: float = 0.0               # additive TTFT slack in the deadline
    remaining: float = 0.0           # tokens left (fluid)
    start: Optional[float] = None    # first service
    finish: Optional[float] = None
    service: float = 0.0             # seconds of (inflated) execution
    canceled: bool = False

    @property
    def deadline(self) -> float:
        return self.arrival + self.slack + self.n_tokens * self.tbt_slo

    @property
    def latency(self) -> Optional[float]:
        return None if self.finish is None else self.finish - self.arrival

    @property
    def observed_tbt(self) -> Optional[float]:
        lat = self.latency
        return None if lat is None else lat / max(self.n_tokens, 1)

    @property
    def service_tbt(self) -> Optional[float]:
        if self.finish is None:
            return None
        return self.service / max(self.n_tokens, 1)

    def met_slo(self, now: float) -> Optional[bool]:
        """True/False once resolved; None while censored (unfinished and
        the deadline has not passed) or canceled."""
        if self.canceled:
            return None
        if self.finish is not None:
            return self.finish <= self.deadline
        return False if now > self.deadline else None


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def _tbt_stats(recs: List[RequestRecord]) -> Dict[str, float]:
    obs = [r.observed_tbt for r in recs if r.observed_tbt is not None]
    srv = [r.service_tbt for r in recs if r.service_tbt is not None]
    return {
        "observed_p50_ms": _pct(obs, 50) * 1e3,
        "observed_p99_ms": _pct(obs, 99) * 1e3,
        "service_p50_ms": _pct(srv, 50) * 1e3,
        "service_p99_ms": _pct(srv, 99) * 1e3,
    }


def _attainment(recs: List[RequestRecord], now: float) -> Dict[str, float]:
    met = missed = 0
    for r in recs:
        ok = r.met_slo(now)
        if ok is True:
            met += 1
        elif ok is False:
            missed += 1
    resolved = met + missed
    return {
        "resolved": resolved,
        "met": met,
        "missed": missed,
        "attainment": met / resolved if resolved else 1.0,
    }


def compute_report(trace, records: List[RequestRecord], fleet, now: float,
                   busy: Mapping[str, float],
                   resident_time: Mapping[str, float],
                   gain_samples: List[float]) -> Dict:
    """Fold the simulation into one JSON-ready report (everything a
    deterministic function of the trace + fleet replay, so two runs of
    the same seed produce identical reports)."""
    by_class: Dict[str, List[RequestRecord]] = {}
    by_tenant: Dict[str, List[RequestRecord]] = {}
    for r in records:
        by_class.setdefault(r.priority, []).append(r)
        by_tenant.setdefault(r.tenant, []).append(r)

    completed = [r for r in records if r.finish is not None]
    canceled = [r for r in records if r.canceled]
    good_tokens = sum(r.n_tokens for r in completed
                      if r.met_slo(now) is True)
    elapsed = max(now, 1e-9)

    per_tenant = {}
    for name, recs in sorted(by_tenant.items()):
        att = _attainment(recs, now)
        spec = trace.tenants.get(name)
        per_tenant[name] = {
            "priority": spec.priority if spec else "?",
            "arch": spec.arch if spec else "?",
            "requests": len(recs),
            "completed": sum(1 for r in recs if r.finish is not None),
            **att,
        }

    report = {
        "trace": trace.summary(),
        "requests": {
            "total": len(records),
            "completed": len(completed),
            "canceled": len(canceled),
            "unfinished": len(records) - len(completed) - len(canceled),
        },
        "slo": {
            "overall": _attainment(records, now),
            "per_class": {cls: _attainment(recs, now)
                          for cls, recs in sorted(by_class.items())},
        },
        "tbt": {cls: _tbt_stats(recs)
                for cls, recs in sorted(by_class.items())},
        "goodput": {
            "tokens_per_s": sum(r.n_tokens for r in completed) / elapsed,
            "slo_met_tokens_per_s": good_tokens / elapsed,
            "requests_per_s": len(completed) / elapsed,
        },
        "fleet": {
            "evictions": fleet.stats["evicted"],
            "migrations": fleet.stats["migrated"],
            "displaced": fleet.stats["displaced"],
            "replans": fleet.stats["replans"],
            # scoped-repair accounting (repair latencies are wall-clock
            # and deliberately NOT reported — touched counts are the
            # deterministic width metric)
            "scoped_repairs": fleet.stats.get("scoped_repairs", 0),
            "full_replays": fleet.stats.get("full_replays", 0),
            "repair_fallbacks": fleet.stats.get("repair_fallbacks", 0),
            "repair_touched_p95": _pct(
                [float(r.devices_touched)
                 for r in getattr(fleet, "repairs", [])], 95),
            "device_deaths": fleet.stats["device_deaths"],
            "event_loop_errors": fleet.stats["errors"],
            "rejected_arrivals": fleet.stats["rejected"],
            "scenarios_solved": fleet.stats["scenarios_solved"],
            "decisions": len(fleet.decisions),
        },
        "devices": {
            "utilization": {
                did: (busy.get(did, 0.0)
                      / max(resident_time.get(did, 0.0), 1e-9))
                for did in sorted(fleet.devices)},
            "mean_gain": (float(np.mean(gain_samples))
                          if gain_samples else 0.0),
            "states": {did: d.state
                       for did, d in sorted(fleet.devices.items())},
        },
        "per_tenant": per_tenant,
        "calib": {
            "observations": fleet.stats.get("calib_observations", 0),
            "flags": fleet.stats.get("calib_flags", 0),
            "refits": fleet.stats.get("calib_refits", 0),
            "flagged_tenants": sorted(set(
                getattr(getattr(fleet, "calib", None), "flag_log",
                        ()) or ())),
        },
    }
    return report
