"""Trace-driven multi-tenant serving simulation (`repro.sim`).

The closed loop the estimator/scheduler/fleet stack is ultimately judged
by: deterministic diurnal/bursty request traces (`traces`), a
virtual-clock simulator that feeds them through ``FleetScheduler`` and
serves requests at interference-inflated rates (`simulator`), and
per-request / per-tenant SLO-attainment and tail-latency metrics
(`metrics`).  Gated in CI by ``benchmarks/bench_trace.py``.
"""
from repro.sim.metrics import RequestRecord, compute_report  # noqa: F401
from repro.sim.simulator import SimConfig, Simulator  # noqa: F401
from repro.sim.traces import (Trace, TraceConfig, TenantSpec,  # noqa: F401
                              generate_trace, request, tenant_profile)
