"""Fault-tolerant checkpointing.

Design for 1000+ nodes (scaled down to single-host here, same protocol):
  * ASYNC save: device->host transfer on the caller thread (cheap), file
    write on a background thread so the train loop never blocks on disk;
  * ATOMIC publish: write to ``step_XXXX.tmp/``, fsync, rename — a crash
    mid-write never corrupts the latest checkpoint;
  * keep-K retention + ``latest`` resolution by scanning valid manifests;
  * MESH-FREE format: leaves are stored as full logical arrays + a JSON
    manifest of the pytree structure, so restore can re-shard onto ANY
    mesh (elastic rescale: restore after changing chip count re-lays-out
    via device_put with the new sharding).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # ----------------------------- save ------------------------------ #
    def save(self, step: int, tree: Any, block: bool = False):
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]   # device -> host now
        t = threading.Thread(target=self._write, args=(step, host_leaves),
                             daemon=True)
        t.start()
        self._pending = t
        if block:
            self.wait()

    @staticmethod
    def _to_native(l: np.ndarray):
        """npz can't store ml_dtypes (bfloat16/f8): persist a byte view."""
        if l.dtype.kind == "V" or str(l.dtype) in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            return l.view(np.uint8), str(l.dtype)
        return l, str(l.dtype)

    def _write(self, step: int, leaves):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        shutil.rmtree(tmp, ignore_errors=True)
        tmp.mkdir(parents=True)
        natives, dtypes = zip(*(self._to_native(l) for l in leaves)) \
            if leaves else ((), ())
        np.savez(tmp / "leaves.npz",
                 **{f"leaf_{i}": l for i, l in enumerate(natives)})
        manifest = {"step": step, "n_leaves": len(leaves),
                    "time": time.time(),
                    "dtypes": list(dtypes),
                    "shapes": [list(l.shape) for l in leaves]}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        os.replace(tmp, final)                     # atomic publish
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------- restore ---------------------------- #
    def all_steps(self):
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return out

    def restore(self, step: int, like: Any = None, shardings: Any = None):
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "leaves.npz")
        leaves = []
        for i in range(manifest["n_leaves"]):
            l = data[f"leaf_{i}"]
            dt = manifest["dtypes"][i]
            if l.dtype == np.uint8 and dt != "uint8":
                import ml_dtypes
                l = l.view(np.dtype(getattr(ml_dtypes, dt, dt)))
            leaves.append(l)
        if like is not None:
            _, treedef = _flatten(like)
            tree = jax.tree.unflatten(treedef, leaves)
            if shardings is not None:
                tree = jax.device_put(tree, shardings)  # elastic re-shard
            else:
                tree = jax.tree.map(
                    lambda l, ref: jax.numpy.asarray(
                        l, getattr(ref, "dtype", None)), tree, like)
            return tree
        # no reference tree: return a flat-leaf reconstruction
        return leaves

    def restore_latest(self, like: Any = None, shardings: Any = None
                       ) -> Optional[Tuple[int, Any]]:
        steps = self.all_steps()
        if not steps:
            return None
        # skip corrupt newest checkpoints (crash-mid-rename safety)
        for s in reversed(steps):
            try:
                return s, self.restore(s, like, shardings)
            except Exception:
                continue
        return None
