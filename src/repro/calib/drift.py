"""Online drift monitor — when the model and the hardware disagree, say so.

Calibration decays: a tenant changes its batch shape, a compiler upgrade
moves a kernel off the MXU, and the fitted profile quietly stops
predicting.  ``DriftMonitor`` watches every resident workload's
predicted-vs-observed slowdown as an EWMA of ``ln(observed/predicted)``
(log-space so over- and under-prediction are symmetric), flags a tenant
whose smoothed divergence exceeds the threshold after a warmup count,
and can **re-fit** the flagged workload from its recent observations —
a 1-D demand-scale search through the estimator against the stored
colocation contexts, which fixes the dominant drift mode (the workload
got uniformly heavier/lighter) without a full sweep.

``FleetScheduler.attach_calibration`` wires a monitor into the fleet
event loop; ``repro.sim`` feeds it per-tick observations and surfaces
the counters in the sim report (bench_calib gates flag/refit behaviour
and bit-identical reports).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimator import solve_scenarios
from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import DeviceModel
from repro.core.scenario import Scenario


@dataclass(frozen=True)
class DriftConfig:
    alpha: float = 0.3           # EWMA smoothing of ln(obs/pred)
    threshold: float = 0.15      # flag when |ewma| > ln(1+threshold)
    warmup: int = 5              # observations before flagging is allowed
    history: int = 32            # stored samples per workload (refit data)
    max_refits: int = 5          # per-workload refit budget
    scale_grid: int = 13         # candidates per refit search stage


@dataclass(frozen=True)
class DriftSample:
    """One observation with enough context to re-predict it later: the
    colocation the workload was in when the slowdown was observed."""
    observed: float
    predicted: float
    background: Tuple[KernelProfile, ...]
    slot_fraction: Optional[Mapping[str, float]]
    device: DeviceModel


@dataclass
class _State:
    ewma: float = 0.0
    count: int = 0
    flagged: bool = False
    refits: int = 0
    samples: Deque[DriftSample] = field(default_factory=deque)


class DriftMonitor:
    """Per-workload EWMA drift detection + observation-driven re-fit."""

    def __init__(self, cfg: DriftConfig = DriftConfig()):
        self.cfg = cfg
        self._states: Dict[str, _State] = {}
        self.flag_log: List[str] = []    # every name ever flagged, in order
        self.observations = 0

    # -------------------------------------------------------------- #
    #  Observation path                                               #
    # -------------------------------------------------------------- #
    def observe(self, name: str, predicted: float, observed: float,
                background: Sequence[KernelProfile] = (),
                slot_fraction: Optional[Mapping[str, float]] = None,
                device: Optional[DeviceModel] = None) -> bool:
        """Record one predicted-vs-observed pair; returns True iff this
        observation NEWLY flags the workload."""
        st = self._states.setdefault(name, _State())
        if len(st.samples) >= self.cfg.history:
            st.samples.popleft()
        if device is not None:
            st.samples.append(DriftSample(
                float(observed), float(predicted), tuple(background),
                dict(slot_fraction) if slot_fraction else None, device))
        r = math.log(max(observed, 1e-9) / max(predicted, 1e-9))
        st.ewma = r if st.count == 0 else \
            self.cfg.alpha * r + (1.0 - self.cfg.alpha) * st.ewma
        st.count += 1
        self.observations += 1
        if st.flagged or st.count < self.cfg.warmup:
            return False
        if abs(st.ewma) > math.log1p(self.cfg.threshold):
            st.flagged = True
            self.flag_log.append(name)
            return True
        return False

    def is_flagged(self, name: str) -> bool:
        st = self._states.get(name)
        return bool(st and st.flagged)

    @property
    def flagged(self) -> List[str]:
        return sorted(n for n, s in self._states.items() if s.flagged)

    @property
    def flags(self) -> int:
        return len(self.flag_log)

    @property
    def refits(self) -> int:
        return sum(s.refits for s in self._states.values())

    def divergence(self, name: str) -> float:
        """Current smoothed |obs/pred − 1| estimate (0 if unseen)."""
        st = self._states.get(name)
        return math.expm1(abs(st.ewma)) if st and st.count else 0.0

    def forget(self, name: str) -> None:
        """Workload left the fleet — drop its state entirely."""
        self._states.pop(name, None)

    # -------------------------------------------------------------- #
    #  Re-fit path                                                    #
    # -------------------------------------------------------------- #
    def can_refit(self, name: str) -> bool:
        st = self._states.get(name)
        return bool(st and st.samples
                    and st.refits < self.cfg.max_refits)

    def refit(self, name: str,
              believed: WorkloadProfile) -> Optional[WorkloadProfile]:
        """Re-fit ``believed`` from the stored observations: search a
        global demand scale ``s`` (all kernel demands × s) minimizing
        squared relative error of re-predicted vs observed slowdowns
        over the sample history, coarse log grid then one refinement.
        Returns the corrected profile (and resets the drift state), or
        None when no samples / refit budget is spent."""
        st = self._states.get(name)
        if st is None or not st.samples \
                or st.refits >= self.cfg.max_refits:
            return None
        # fit against the samples that actually diverged: the history
        # spans the shift boundary, and pre-shift obs==pred samples
        # would drag the scale back toward 1 (costing extra
        # flag-refit-flag rounds before convergence)
        gate = 0.5 * math.log1p(self.cfg.threshold)
        samples = [s for s in st.samples
                   if abs(math.log(max(s.observed, 1e-9)
                                   / max(s.predicted, 1e-9))) > gate]
        if not samples:
            samples = list(st.samples)
        dev = samples[0].device
        t_believed = max(believed.total_time(dev), 1e-12)

        def candidates_for(scales: np.ndarray) -> np.ndarray:
            # price each candidate exactly like the fleet does: the
            # workload's ACTUAL kernels as victims (a representative
            # kernel renormalizes away the demand scale we are trying
            # to recover), folded duration-weighted, rebased to the
            # believed baseline the observations were recorded against
            scenarios = []
            rebase = np.empty(len(scales), np.float64)
            weights = []
            for i, s in enumerate(scales):
                w = scale_workload(believed, float(s))
                rebase[i] = w.total_time(dev) / t_believed
                wts = np.asarray([k.isolated_time(dev) * k.duration_weight
                                  for k in w.kernels], np.float64)
                weights.append(wts / max(wts.sum(), 1e-12))
                for k in w.kernels:
                    for smp in samples:
                        scenarios.append(Scenario(
                            (k,), smp.background, smp.slot_fraction,
                            smp.device))
            raw = np.asarray(
                solve_scenarios(scenarios, dev).slowdowns[:, 0],
                np.float64).reshape(len(scales), len(believed.kernels),
                                    len(samples))
            obs = np.asarray([smp.observed for smp in samples], np.float64)
            fold = np.einsum("ck,cks->cs", np.asarray(weights), raw)
            pred = np.maximum(fold * rebase[:, None], 1.0)
            rel = (pred - obs[None, :]) / np.maximum(obs[None, :], 1e-9)
            return np.mean(rel * rel, axis=1)

        # wide coarse grid: for a duration-bound workload every scale
        # below 1/u_max predicts identically (background reps normalize,
        # own demand stays below the water level), so the informative
        # region can sit far from 1 — cover [1/16, 16], then refine
        coarse = np.exp(np.linspace(math.log(1.0 / 16.0), math.log(16.0),
                                    2 * self.cfg.scale_grid - 1))
        losses = candidates_for(coarse)
        s0 = float(coarse[int(np.argmin(losses))])
        fine = s0 * np.exp(np.linspace(-0.35, 0.35, self.cfg.scale_grid))
        losses = candidates_for(fine)
        s1 = float(fine[int(np.argmin(losses))])
        finer = s1 * np.exp(np.linspace(-0.06, 0.06, self.cfg.scale_grid))
        losses = candidates_for(finer)
        s_best = float(finer[int(np.argmin(losses))])

        st.refits += 1
        st.ewma = 0.0
        st.count = 0
        st.flagged = False
        st.samples.clear()
        return scale_workload(believed, s_best)

    def to_json(self) -> Dict[str, object]:
        return {"observations": self.observations,
                "flags": self.flags,
                "refits": self.refits,
                "flagged_tenants": sorted(set(self.flag_log))}


def scale_workload(w: WorkloadProfile, s: float) -> WorkloadProfile:
    kernels = tuple(replace(
        k, demand={r: d * s for r, d in k.demand.items()})
        for k in w.kernels)
    return replace(w, kernels=kernels)
