"""Measurement runner — the paper's §4 stressor×victim sweep as data.

The calibration loop starts here: colocate each victim kernel with a
calibrated single-axis stressor at intensity λ (and with cache-polluter
probes of growing working set), record the victim's observed slowdown,
and hand the resulting ``MeasurementSet`` to the fitter
(``repro.calib.fit``).  The sweep itself is backend-pluggable:

  * ``SyntheticBackend`` — serves slowdowns from HIDDEN ground-truth
    ``KernelProfile``s through the water-filling estimator (optionally
    noised under a seeded ``numpy.random.Generator``).  The whole
    measure→fit→validate pipeline runs in CI without hardware, and the
    hidden truths make round-trip recovery a *checkable* property
    (``benchmarks/bench_calib.py``).
  * ``PallasBackend`` — runs the Pallas stressor kernels
    (``repro.kernels.stressors``) concurrently with real victim
    callables (interpret mode on CPU; the same calls compile to Mosaic
    on TPU) and times the victim with the shared median+IQR repeat
    timer (``median_iqr_time`` — also used by
    ``benchmarks/tpu_native.py``).

A ``Colocation`` names its background *declaratively* — stressor
``(axis, intensity, working_set)`` specs plus cohort victims by name —
so the fitter and validator can rebuild the exact same background from
analytic stressor profiles without ever seeing the hidden truths.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimator import solve_scenarios
from repro.core.profile import KernelProfile
from repro.core.resources import RESOURCE_AXES, DeviceModel
from repro.core.scenario import Scenario
from repro.core.sensitivity import stressor

# the default §4 grids: fit on these λ / working-set points, validate on
# points BETWEEN them (see repro.calib.validate.HOLDOUT_LAMBDAS)
FIT_LAMBDAS: Tuple[float, ...] = (0.1, 0.25, 0.5, 0.75, 0.9)
CACHE_WS_FRACTIONS: Tuple[float, ...] = (0.25, 0.5, 1.0, 2.0)
CACHE_PROBE_INTENSITY = 0.5          # hbm intensity of the polluter probes
# reverse-probe intensities: stressor at λ observed against the measured
# kernel — its slowdown λ/(1−u) resolves victim demands u > 1−λ that
# max-min hides from victim-side probes (u below fair share)
REVERSE_LAMBDAS: Tuple[float, ...] = (0.5, 0.75, 0.9, 0.98)


# ------------------------------------------------------------------ #
#  The shared repeat timer (median + IQR)                              #
# ------------------------------------------------------------------ #
def median_iqr_time(fn: Callable[[], object], repeats: int = 5,
                    warmup: int = 1) -> Tuple[float, float]:
    """Time ``fn`` (blocking on its jax result) ``repeats`` times after
    ``warmup`` untimed calls; return ``(median_s, iqr_s)``.  The one
    timer for every wall-clock kernel measurement — the tpu_native
    stressor suite and the calib Pallas backend both use it, so a
    timing-methodology change lands in one place."""
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    ts = np.empty(max(repeats, 1), np.float64)
    for i in range(len(ts)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts[i] = time.perf_counter() - t0
    return (float(np.median(ts)),
            float(np.percentile(ts, 75) - np.percentile(ts, 25)))


# ------------------------------------------------------------------ #
#  The measurement vocabulary                                          #
# ------------------------------------------------------------------ #
@dataclass(frozen=True)
class StressorSpec:
    """One calibrated stressor: ``intensity`` of ``axis`` capacity (plus
    an optional cache working set for polluter probes).  Maps 1:1 to
    ``repro.core.sensitivity.stressor`` and to the Pallas kernels."""
    axis: str
    intensity: float
    working_set: float = 0.0

    def profile(self, dev: DeviceModel) -> KernelProfile:
        return stressor(self.axis, self.intensity, dev,
                        working_set=self.working_set)


@dataclass(frozen=True)
class Colocation:
    """One colocated run: ``victim`` (by name) next to analytic
    stressors and/or other measured kernels (``cohort``, by name).

    ``observe`` selects which side's slowdown the run records:
    ``"victim"`` (default) times the measured kernel; ``"stressor"``
    times the FIRST stressor while the measured kernel contends as
    background.  Reverse probes are essential, not a nicety: under
    max-min sharing a kernel whose demand sits below the fair share is
    never throttled itself, so victim-side probes carry zero signal
    about it — but the known stressor's slowdown reveals exactly how
    much of the axis the kernel takes away (§4 measures both sides).
    """
    victim: str
    stressors: Tuple[StressorSpec, ...] = ()
    cohort: Tuple[str, ...] = ()
    observe: str = "victim"

    @property
    def single_axis(self) -> Optional[str]:
        """The axis of a pure single-stressor probe (else None)."""
        if len(self.stressors) == 1 and not self.cohort \
                and self.observe == "victim" \
                and self.stressors[0].working_set == 0.0:
            return self.stressors[0].axis
        return None

    @property
    def is_cache_probe(self) -> bool:
        return any(s.working_set > 0.0 for s in self.stressors)


@dataclass
class MeasurementSet:
    """The sweep's output: observations + per-victim isolated times,
    everything the fitter needs (and nothing the backend should hide)."""
    device: DeviceModel
    colocations: List[Colocation]
    slowdowns: np.ndarray                # (n,) observed victim slowdowns
    isolated_times: Dict[str, float]     # victim -> measured t_iso (s)

    def __len__(self) -> int:
        return len(self.colocations)

    def of_victim(self, name: str) -> Tuple[List[Colocation], np.ndarray]:
        idx = [i for i, c in enumerate(self.colocations) if c.victim == name]
        return [self.colocations[i] for i in idx], self.slowdowns[idx]

    @property
    def victims(self) -> List[str]:
        return sorted(self.isolated_times)


def colocation_scenario(c: Colocation, victim_profile: KernelProfile,
                        dev: DeviceModel,
                        cohort: Mapping[str, KernelProfile]) -> Scenario:
    """Lower a Colocation to the estimator query whose first victim row
    is the OBSERVED kernel — the measured kernel itself, or (reverse
    probes) the first stressor with the measured kernel as background.
    The one lowering both backends and the fitter share, so a fitted
    candidate is scored under exactly the semantics it was measured."""
    stress = tuple(s.profile(dev) for s in c.stressors)
    others = tuple(cohort[n] for n in c.cohort)
    if c.observe == "stressor":
        if not stress:
            raise ValueError("observe='stressor' needs a stressor")
        return Scenario((stress[0],),
                        stress[1:] + (victim_profile,) + others)
    return Scenario((victim_profile,), stress + others)


def sweep_colocations(victims: Sequence[str], dev: DeviceModel,
                      axes: Sequence[str] = RESOURCE_AXES,
                      lambdas: Sequence[float] = FIT_LAMBDAS,
                      cache_ws_fractions: Sequence[float] = CACHE_WS_FRACTIONS
                      ) -> List[Colocation]:
    """The §4 calibration sweep: every victim × every axis × every λ as
    single-stressor probes, same-axis multi-stressor probes (under
    max-min sharing a single stressor can't throttle a victim below the
    1/2 fair share — k saturating stressors lower the victim's share to
    1/(k+1), exposing demands down there), plus hbm polluter probes with
    working sets swept around the device cache capacity (the Fig. 3
    cliff — what identifies ``cache_working_set``/``cache_hit_fraction``)."""
    out: List[Colocation] = []
    for v in victims:
        for axis in axes:
            for lam in lambdas:
                out.append(Colocation(v, (StressorSpec(axis, lam),)))
            for k in (2, 3):
                out.append(Colocation(
                    v, tuple(StressorSpec(axis, 0.9) for _ in range(k))))
            for lam in REVERSE_LAMBDAS:
                out.append(Colocation(v, (StressorSpec(axis, lam),),
                                      observe="stressor"))
        for f in cache_ws_fractions:
            out.append(Colocation(v, (StressorSpec(
                "hbm", CACHE_PROBE_INTENSITY,
                working_set=f * dev.cache_capacity),)))
    return out


# ------------------------------------------------------------------ #
#  Synthetic backend: hidden truth through the estimator               #
# ------------------------------------------------------------------ #
class SyntheticBackend:
    """Serve measurements from hidden ground-truth profiles.

    The backend is the only holder of ``truth``; consumers see nothing
    but observed slowdowns and isolated times — exactly the information
    a hardware run would yield.  With ``noise > 0`` every observation is
    multiplied by ``exp(noise * N(0, 1))`` drawn from a Generator seeded
    at construction, so repeated identical call sequences stay
    bit-identical per seed.
    """

    def __init__(self, truth: Mapping[str, KernelProfile],
                 dev: DeviceModel, noise: float = 0.0, seed: int = 0):
        self._truth = dict(truth)
        self.device = dev
        self.noise = float(noise)
        self._rng = np.random.default_rng(seed)

    def isolated_time(self, victim: str) -> float:
        return float(self._truth[victim].isolated_time(self.device))

    def measure(self, colocations: Sequence[Colocation]) -> np.ndarray:
        """Observed victim slowdowns, one per colocation, in order —
        ONE batched estimator solve over the hidden truths."""
        colocations = list(colocations)
        if not colocations:
            return np.zeros(0, np.float64)
        scenarios = [colocation_scenario(c, self._truth[c.victim],
                                         self.device, self._truth)
                     for c in colocations]
        slows = solve_scenarios(scenarios, self.device).slowdowns[:, 0]
        slows = np.asarray(slows, np.float64).copy()
        if self.noise > 0:
            slows *= np.exp(self.noise
                            * self._rng.standard_normal(len(slows)))
        return slows

    def run_sweep(self, victims: Sequence[str],
                  axes: Sequence[str] = RESOURCE_AXES,
                  lambdas: Sequence[float] = FIT_LAMBDAS,
                  cache_ws_fractions: Sequence[float] = CACHE_WS_FRACTIONS
                  ) -> MeasurementSet:
        cols = sweep_colocations(victims, self.device, axes, lambdas,
                                 cache_ws_fractions)
        return MeasurementSet(
            self.device, cols, self.measure(cols),
            {v: self.isolated_time(v) for v in victims})


# ------------------------------------------------------------------ #
#  Pallas backend: real colocated kernel runs                          #
# ------------------------------------------------------------------ #
# Per-axis stressor kernels (repro.kernels.stressors).  Intensity scales
# the work per dispatch; on real hardware the loop thread keeps the axis
# busy for the victim's whole run.  Absolute intensity calibration
# (λ of peak) needs TPU time — see ROADMAP item 4.
_STRESSOR_TILE = 128


def _stressor_call(spec: StressorSpec, interpret: bool) -> Callable[[], object]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import stressors

    lam = max(min(spec.intensity, 1.0), 0.05)
    key = jax.random.PRNGKey(17)
    if spec.axis == "mxu":
        a = jax.random.normal(key, (2, _STRESSOR_TILE, _STRESSOR_TILE),
                              jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(18),
                              (_STRESSOR_TILE, _STRESSOR_TILE),
                              jnp.float32) * 0.1
        iters = max(1, int(round(32 * lam)))
        return lambda: stressors.stress_mxu(a, b, iters=iters,
                                            interpret=interpret)
    if spec.axis in ("vpu", "issue"):
        x = jax.random.normal(key, (256, _STRESSOR_TILE), jnp.float32)
        iters = max(1, int(round(64 * lam)))
        return lambda: stressors.stress_vpu(x, iters=iters, ilp=4,
                                            interpret=interpret)
    if spec.axis in ("hbm", "l2", "ici"):
        ws = spec.working_set or 8 * (1 << 20)
        rows = max(8, int(ws / (4 * _STRESSOR_TILE)))
        rows = 8 * max(1, round(rows / 8 * lam))
        x = jax.random.normal(key, (rows, _STRESSOR_TILE), jnp.float32)
        return lambda: stressors.stress_hbm(x, interpret=interpret)
    if spec.axis == "smem":
        x = jax.random.normal(key, (512, _STRESSOR_TILE), jnp.float32)
        iters = max(1, int(round(32 * lam)))
        return lambda: stressors.stress_vmem(x, iters=iters, stride=8,
                                             interpret=interpret)
    raise ValueError(f"no Pallas stressor for axis {spec.axis!r}")


class PallasBackend:
    """Measure real colocated runs: victim callables timed (median of N
    repeats — the shared ``median_iqr_time``) while stressor kernels
    loop on background threads.

    ``victims`` maps a name to a zero-arg callable issuing the victim
    kernel (returning a jax value to block on).  On CPU the kernels run
    in interpret mode and "colocation" is thread-level concurrency —
    enough to smoke-test the pipeline end to end; on TPU the identical
    calls lower to Mosaic and genuinely contend (the ROADMAP's
    real-hardware item).  Wall-clock based, hence NOT deterministic —
    CI gates use ``SyntheticBackend``.
    """

    def __init__(self, victims: Mapping[str, Callable[[], object]],
                 dev: DeviceModel, repeats: int = 5,
                 interpret: Optional[bool] = None):
        import jax
        self._victims = dict(victims)
        self.device = dev
        self.repeats = int(repeats)
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self._iso: Dict[str, float] = {}

    def isolated_time(self, victim: str) -> float:
        t = self._iso.get(victim)
        if t is None:
            t, _ = median_iqr_time(self._victims[victim],
                                   repeats=self.repeats)
            self._iso[victim] = t
        return t

    def _stressor_iso(self, spec: StressorSpec) -> float:
        t = self._iso.get(repr(spec))
        if t is None:
            t, _ = median_iqr_time(_stressor_call(spec, self.interpret),
                                   repeats=self.repeats)
            self._iso[repr(spec)] = t
        return t

    def _timed_colocation(self, timed: Callable[[], object],
                          background: Sequence[Callable[[], object]]
                          ) -> float:
        import threading

        import jax

        stop = threading.Event()

        def spin(fn):
            while not stop.is_set():
                jax.block_until_ready(fn())

        threads = [threading.Thread(target=spin, args=(fn,), daemon=True)
                   for fn in background]
        for th in threads:
            th.start()
        try:
            t, _ = median_iqr_time(timed, repeats=self.repeats)
        finally:
            stop.set()
            for th in threads:
                th.join()
        return t

    def measure(self, colocations: Sequence[Colocation]) -> np.ndarray:
        out = np.empty(len(colocations), np.float64)
        for i, c in enumerate(colocations):
            if c.cohort:
                raise NotImplementedError(
                    "PallasBackend measures stressor backgrounds; "
                    "victim-cohort mixes need per-victim callables "
                    "running concurrently (real-TPU work, ROADMAP 4)")
            fns = [_stressor_call(s, self.interpret) for s in c.stressors]
            if c.observe == "stressor":
                iso = self._stressor_iso(c.stressors[0])
                col = self._timed_colocation(
                    fns[0], fns[1:] + [self._victims[c.victim]])
            else:
                iso = self.isolated_time(c.victim)
                col = self._timed_colocation(self._victims[c.victim], fns)
            out[i] = max(col / max(iso, 1e-12), 1.0)
        return out

    def run_sweep(self, victims: Sequence[str],
                  axes: Sequence[str] = RESOURCE_AXES,
                  lambdas: Sequence[float] = FIT_LAMBDAS,
                  cache_ws_fractions: Sequence[float] = ()
                  ) -> MeasurementSet:
        cols = sweep_colocations(list(victims), self.device, axes, lambdas,
                                 cache_ws_fractions)
        return MeasurementSet(
            self.device, cols, self.measure(cols),
            {v: self.isolated_time(v) for v in victims})
