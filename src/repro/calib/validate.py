"""Held-out validation — score fitted profiles on mixes the fitter never saw.

The fit sweep uses single-stressor probes; a fit that only reproduces
its own training points is just a second analytic model (PAPERS.md,
"Characterizing ... Workloads Under Interference").  This module builds
*held-out* colocations — k-way victim+cohort mixes and off-grid stressor
intensities — measures them on the backend (which knows the hidden
truth), predicts them with the fitted profiles, and reports per-mix and
per-axis relative error.  ``ValidationReport.max_rel_error`` is the
number the bench gate holds under 5%.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from repro.calib.fit import predict_slowdowns
from repro.calib.measure import Colocation, StressorSpec
from repro.core.profile import KernelProfile
from repro.core.resources import RESOURCE_AXES

# intensities BETWEEN the fit grid points (FIT_LAMBDAS) — per-axis
# generalization off the training grid
HOLDOUT_LAMBDAS: Tuple[float, ...] = (0.33, 0.66, 0.85)


def holdout_mixes(names: Sequence[str], rng: np.random.Generator,
                  n_mixes: int = 24, ks: Sequence[int] = (2, 3),
                  axes: Sequence[str] = RESOURCE_AXES,
                  lambdas: Sequence[float] = HOLDOUT_LAMBDAS
                  ) -> List[Colocation]:
    """Held-out plan: per-axis off-grid stressor probes for every victim,
    plus ``n_mixes`` random k-way victim+cohort colocations (optionally
    with one random stressor riding along).  Seeded → reproducible."""
    names = list(names)
    out: List[Colocation] = []
    for v in names:
        for axis in axes:
            for lam in lambdas:
                out.append(Colocation(v, (StressorSpec(axis, lam),)))
    if len(names) >= 2:
        for _ in range(n_mixes):
            k = int(rng.choice(list(ks)))
            k = min(k, len(names))
            picks = list(rng.choice(names, size=k, replace=False))
            victim, cohort = picks[0], tuple(picks[1:])
            stressors: Tuple[StressorSpec, ...] = ()
            if rng.random() < 0.5:
                axis = str(rng.choice(list(axes)))
                stressors = (StressorSpec(
                    axis, float(rng.uniform(0.2, 0.8))),)
            out.append(Colocation(victim, stressors, cohort))
    return out


@dataclass
class ValidationReport:
    device: str
    n_mixes: int
    max_rel_error: float
    mean_rel_error: float
    per_victim: Dict[str, float]          # victim -> max rel error
    per_axis: Dict[str, float]            # axis (single-stressor) -> max
    worst_mix: str = ""

    def to_json(self) -> Dict[str, object]:
        return {"device": self.device, "n_mixes": self.n_mixes,
                "max_rel_error": self.max_rel_error,
                "mean_rel_error": self.mean_rel_error,
                "per_victim": dict(sorted(self.per_victim.items())),
                "per_axis": dict(sorted(self.per_axis.items())),
                "worst_mix": self.worst_mix}


def _mix_label(c: Colocation) -> str:
    parts = [c.victim]
    parts += [f"{s.axis}@{s.intensity:.2f}" for s in c.stressors]
    parts += list(c.cohort)
    return "+".join(parts)


def validate(fitted: Mapping[str, KernelProfile], backend,
             mixes: Sequence[Colocation]) -> ValidationReport:
    """Measure ``mixes`` on ``backend`` (truth), predict them with
    ``fitted``, report relative error.  Backend is any object with
    ``measure(colocations) -> np.ndarray`` and a ``device`` attr —
    Synthetic in CI, Pallas on hardware."""
    mixes = list(mixes)
    dev = backend.device
    observed = np.asarray(backend.measure(mixes), np.float64)
    predicted = predict_slowdowns(fitted, mixes, dev)
    rel = np.abs(predicted - observed) / np.maximum(observed, 1e-9)

    per_victim: Dict[str, float] = {}
    per_axis: Dict[str, float] = {}
    for i, c in enumerate(mixes):
        per_victim[c.victim] = max(per_victim.get(c.victim, 0.0),
                                   float(rel[i]))
        axis = c.single_axis
        if axis is not None:
            per_axis[axis] = max(per_axis.get(axis, 0.0), float(rel[i]))
    worst = int(np.argmax(rel)) if len(rel) else 0
    return ValidationReport(
        device=dev.name, n_mixes=len(mixes),
        max_rel_error=float(np.max(rel)) if len(rel) else 0.0,
        mean_rel_error=float(np.mean(rel)) if len(rel) else 0.0,
        per_victim=per_victim, per_axis=per_axis,
        worst_mix=_mix_label(mixes[worst]) if len(mixes) else "")
