"""repro.calib — measured-profile calibration: close the model-to-hardware loop.

The paper's core contribution is *measuring* per-resource interference
sensitivity; this package turns the repo's analytic KernelProfiles into
fitted, validated, drift-monitored ones:

    measure  →  fit  →  validate  →  monitor  →  re-fit
  (calib.measure) (calib.fit) (calib.validate) (calib.drift)

* ``measure`` runs the §4 stressor×victim sweep behind a pluggable
  backend (deterministic ``SyntheticBackend`` for CI, ``PallasBackend``
  for real colocated kernel runs);
* ``fit`` inverts the water-filling estimator over the measured
  slowdown matrix (batched coordinate descent; ``solve_scenarios`` is
  the forward model on whichever solver backend PR 8's switch selects);
* ``validate`` scores the fit on held-out k-way mixes the fitter never
  saw;
* ``drift`` watches predicted-vs-observed slowdown online inside
  ``FleetScheduler``/``repro.sim`` and re-fits flagged tenants.

CI gate: ``benchmarks/bench_calib.py`` (BENCH_calib.json).
"""
from repro.calib.drift import (DriftConfig, DriftMonitor, DriftSample,
                               scale_workload)
from repro.calib.fit import (FitConfig, FitReport, fit_kernel, fit_profiles,
                             fit_report, params_to_profile, perturb_profile,
                             predict_slowdowns, profile_to_params)
from repro.calib.measure import (CACHE_WS_FRACTIONS, FIT_LAMBDAS,
                                 REVERSE_LAMBDAS, Colocation,
                                 MeasurementSet, PallasBackend,
                                 StressorSpec, SyntheticBackend,
                                 colocation_scenario, median_iqr_time,
                                 sweep_colocations)
from repro.calib.validate import (HOLDOUT_LAMBDAS, ValidationReport,
                                  holdout_mixes, validate)

__all__ = [
    "CACHE_WS_FRACTIONS", "Colocation", "DriftConfig", "DriftMonitor",
    "DriftSample", "FIT_LAMBDAS", "FitConfig", "FitReport",
    "HOLDOUT_LAMBDAS", "MeasurementSet", "PallasBackend",
    "REVERSE_LAMBDAS", "StressorSpec", "SyntheticBackend",
    "ValidationReport", "colocation_scenario", "fit_kernel",
    "fit_profiles", "fit_report", "holdout_mixes", "median_iqr_time",
    "params_to_profile", "perturb_profile", "predict_slowdowns",
    "profile_to_params", "scale_workload", "sweep_colocations",
    "validate",
]
