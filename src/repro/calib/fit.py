"""Profile fitter — invert the water-filling estimator over measurements.

The estimator already answers "given profiles, what slowdowns?"; this
module answers the calibration question "given observed slowdowns, what
profiles?" by batched coordinate descent *through* the estimator —
``solve_scenarios`` is the forward model, so whatever backend the
PR 8 switch selects (numpy or jax) prices the candidate grids.

Parameterization per victim kernel (9 scalars):

  * ``u[axis] ∈ [0, 1]`` for the 7 resource axes — fraction of the axis
    the kernel occupies while running.  ``demand[axis] = u·C_axis·t_iso``
    with the measured isolated time as duration, so the fitted profile
    reproduces t_iso exactly and `utilization()` returns ``u``.
  * ``cache_working_set ≥ 0`` and ``cache_hit_fraction ∈ [0, 1]`` — the
    Fig. 3 cache cliff knobs, identified by the polluter probes in the
    sweep.  The hbm *raw* demand is back-solved through the cache
    discount so ``u[hbm]`` stays the observed isolated utilization.

Descent: round 1 sweeps each parameter over a global grid (full [0,1]
coverage — no reliance on the knee init), later rounds shrink to local
grids; every candidate×observation product is priced in ONE batched
solve, so a full fit is a handful of few-hundred-scenario solves.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.calib.measure import (Colocation, MeasurementSet,
                                 colocation_scenario)
from repro.core.estimator import solve_scenarios
from repro.core.profile import KernelProfile
from repro.core.resources import RESOURCE_AXES, DeviceModel
from repro.core.scenario import Scenario

_U_KEYS = tuple(f"u:{axis}" for axis in RESOURCE_AXES)
_WS_KEY = "ws"
_HIT_KEY = "hit"
PARAM_KEYS: Tuple[str, ...] = _U_KEYS + (_WS_KEY, _HIT_KEY)


@dataclass(frozen=True)
class FitConfig:
    rounds: int = 3
    grid: int = 11                    # candidates per parameter sweep
    local_spans: Tuple[float, ...] = (0.2, 0.07)   # rounds 2, 3, ... (u/hit)
    # round-1 refinement of each u on the clean probes, BEFORE the cache
    # sweep: the clean-subset loss is direct in u (reverse probes read it
    # off as λ/(1−u)), and a u pinned to grid resolution there would
    # otherwise be "compensated" by the cache knobs into a joint local
    # minimum no single-coordinate move escapes
    clean_refine_spans: Tuple[float, ...] = (0.05, 0.015)
    # cache working-set candidates (fractions of device cache capacity).
    # Under the thrash-cliff cache model ws is only identifiable to an
    # interval between polluter-probe thresholds (cache − probe_ws), so
    # cover the midpoints of the intervals the default probe working
    # sets (CACHE_WS_FRACTIONS) carve out
    ws_fractions: Tuple[float, ...] = (0.1, 0.25, 0.375, 0.5, 0.625,
                                       0.75, 0.875, 1.0, 1.5, 2.0, 4.0)
    min_improvement: float = 1e-12    # keep incumbent unless strictly better
    fit_cache: bool = True            # sweep ws/hit (off for cache-free fits)


def params_to_profile(name: str, params: Mapping[str, float],
                      t_iso: float, dev: DeviceModel) -> KernelProfile:
    """Materialize a candidate parameter vector as a KernelProfile whose
    isolated behaviour matches (t_iso, u) by construction."""
    ws = max(float(params.get(_WS_KEY, 0.0)), 0.0)
    hit = min(max(float(params.get(_HIT_KEY, 0.0)), 0.0), 1.0)
    if ws <= 0.0:
        hit = 0.0
    demand: Dict[str, float] = {}
    for axis in RESOURCE_AXES:
        u = min(max(float(params.get(f"u:{axis}", 0.0)), 0.0), 1.0)
        demand[axis] = u * dev.capacity(axis) * t_iso
    if hit > 0.0:
        # invert the isolated cache discount: effective_demand multiplies
        # raw hbm by (1 - hit·resident) at cache_share=1
        resident = min(1.0, dev.cache_capacity / max(ws, 1.0))
        demand["hbm"] /= max(1.0 - hit * resident, 1e-6)
    return KernelProfile(name, demand=demand, duration=t_iso,
                         cache_working_set=ws,
                         cache_hit_fraction=hit if ws > 0 else 0.0)


def profile_to_params(k: KernelProfile, dev: DeviceModel) -> Dict[str, float]:
    """The inverse map (for tests / warm starts): observed isolated
    utilization + cache knobs."""
    u = k.utilization(dev)
    params = {f"u:{axis}": u[axis] for axis in RESOURCE_AXES}
    params[_WS_KEY] = k.cache_working_set
    params[_HIT_KEY] = k.cache_hit_fraction
    return params


def perturb_profile(k: KernelProfile, rng: np.random.Generator,
                    scale: float = 0.3,
                    dev: Optional[DeviceModel] = None) -> KernelProfile:
    """A hidden ground truth for round-trip tests: multiplicatively
    perturb every nonzero demand axis (and duration / cache knobs) by
    ``exp(scale·N(0,1))`` from the caller's seeded Generator."""
    demand = {r: (d * float(np.exp(scale * rng.standard_normal()))
                  if d > 0 else d)
              for r, d in k.demand.items()}
    duration = k.duration
    if duration:
        duration = duration * float(np.exp(scale * rng.standard_normal()))
    ws = k.cache_working_set
    hit = k.cache_hit_fraction
    if ws > 0:
        ws = ws * float(np.exp(scale * rng.standard_normal()))
        hit = float(np.clip(hit + 0.25 * scale * rng.standard_normal(),
                            0.05, 0.95))
    out = replace(k, demand=demand, duration=duration,
                  cache_working_set=ws, cache_hit_fraction=hit)
    if dev is not None:
        # keep the truth physical: no axis may exceed its capacity
        u = out.utilization(dev)
        worst = max(u.values())
        if worst > 1.0:
            out = replace(out, demand={r: d / worst
                                       for r, d in out.demand.items()})
    return out


# ------------------------------------------------------------------ #
#  Loss evaluation: all candidates × all observations, one solve       #
# ------------------------------------------------------------------ #
def predict_slowdowns(profiles: Mapping[str, KernelProfile],
                      colocations: Sequence[Colocation],
                      dev: DeviceModel) -> np.ndarray:
    """Estimator predictions for a measurement plan — the forward model
    the fitter minimizes against and the validator scores with."""
    scenarios = [colocation_scenario(c, profiles[c.victim], dev, profiles)
                 for c in colocations]
    if not scenarios:
        return np.zeros(0, np.float64)
    return np.asarray(
        solve_scenarios(scenarios, dev).slowdowns[:, 0], np.float64)


def _candidate_losses(candidates: Sequence[KernelProfile],
                      colocations: Sequence[Colocation],
                      observed: np.ndarray, dev: DeviceModel,
                      fitted: Mapping[str, KernelProfile]) -> np.ndarray:
    """Mean squared log-relative error per candidate profile; one batched
    solve over len(candidates)×len(colocations) scenarios."""
    scenarios = []
    for cand in candidates:
        for c in colocations:
            scenarios.append(colocation_scenario(c, cand, dev, fitted))
    pred = np.asarray(solve_scenarios(scenarios, dev).slowdowns[:, 0],
                      np.float64)
    pred = pred.reshape(len(candidates), len(colocations))
    err = np.log(np.maximum(pred, 1e-9)) - np.log(np.maximum(observed, 1e-9))
    return np.mean(err * err, axis=1)


def _grids(key: str, current: float, rnd: int, cfg: FitConfig,
           dev: DeviceModel) -> np.ndarray:
    if key == _WS_KEY:
        pts = [0.0] + [f * dev.cache_capacity for f in cfg.ws_fractions]
        if rnd > 0 and current > 0:
            pts += [current * 0.7, current, current * 1.4]
        return np.unique(np.asarray(pts, np.float64))
    if rnd == 0:
        return np.linspace(0.0, 1.0, cfg.grid)
    span = cfg.local_spans[min(rnd - 1, len(cfg.local_spans) - 1)]
    return np.unique(np.clip(
        current + span * np.linspace(-1.0, 1.0, cfg.grid), 0.0, 1.0))


def fit_kernel(name: str, colocations: Sequence[Colocation],
               observed: np.ndarray, t_iso: float, dev: DeviceModel,
               cfg: FitConfig = FitConfig(),
               fitted: Optional[Mapping[str, KernelProfile]] = None,
               init: Optional[Mapping[str, float]] = None) -> KernelProfile:
    """Coordinate descent for one victim kernel."""
    fitted = dict(fitted or {})
    params: Dict[str, float] = {k: 0.0 for k in PARAM_KEYS}
    if init:
        params.update({k: float(v) for k, v in init.items()
                       if k in params})
    colocations = list(colocations)
    clean = [i for i, c in enumerate(colocations) if not c.is_cache_probe]

    def sweep(trials: Sequence[Dict[str, float]],
              subset: Optional[Sequence[int]] = None) -> None:
        nonlocal best
        cols = colocations if subset is None \
            else [colocations[i] for i in subset]
        obs = observed if subset is None else observed[list(subset)]
        cands = []
        for t in trials:
            merged = dict(params)
            merged.update(t)
            cands.append(params_to_profile(name, merged, t_iso, dev))
        losses = _candidate_losses(cands, cols, obs, dev, fitted)
        i = int(np.argmin(losses))
        if subset is not None or losses[i] < best - cfg.min_improvement:
            params.update(trials[i])
        if subset is None and losses[i] < best - cfg.min_improvement:
            best = float(losses[i])

    best = _candidate_losses(
        [params_to_profile(name, params, t_iso, dev)],
        colocations, observed, dev, fitted)[0]
    for rnd in range(cfg.rounds):
        for key in _U_KEYS:
            grid = _grids(key, params[key], rnd, cfg, dev)
            # round 1 settles the utilization axes on the clean probes
            # alone: the cache probes otherwise drag u:hbm toward the
            # thrashed demand and strand (ws, hit) in a local minimum
            sweep([{key: float(v)} for v in grid],
                  subset=clean if rnd == 0 else None)
            if rnd == 0:
                for span in cfg.clean_refine_spans:
                    g = np.unique(np.clip(
                        params[key]
                        + span * np.linspace(-1.0, 1.0, cfg.grid),
                        0.0, 1.0))
                    sweep([{key: float(v)} for v in g], subset=clean)
        if rnd == 0:
            best = _candidate_losses(
                [params_to_profile(name, params, t_iso, dev)],
                colocations, observed, dev, fitted)[0]
        if cfg.fit_cache:
            # (ws, hit) move the loss only jointly — a working set with
            # no hits is inert, a hit fraction with no working set is
            # ignored — so sweep the 2-D grid, then let hbm re-settle
            # (the cache discount and u:hbm trade off directly)
            ws_grid = _grids(_WS_KEY, params[_WS_KEY], rnd, cfg, dev)
            hit_grid = _grids(_HIT_KEY, params[_HIT_KEY], rnd, cfg, dev)
            sweep([{_WS_KEY: float(w), _HIT_KEY: float(h)}
                   for w in ws_grid
                   for h in (hit_grid if w > 0 else [0.0])])
            grid = _grids("u:hbm", params["u:hbm"], rnd, cfg, dev)
            sweep([{"u:hbm": float(v)} for v in grid])
    return params_to_profile(name, params, t_iso, dev)


def fit_profiles(ms: MeasurementSet, cfg: FitConfig = FitConfig(),
                 inits: Optional[Mapping[str, Mapping[str, float]]] = None
                 ) -> Dict[str, KernelProfile]:
    """Fit every victim in a MeasurementSet independently (the sweep's
    single-stressor probes carry no cross-victim coupling; cohort mixes
    are the *validator's* held-out material)."""
    out: Dict[str, KernelProfile] = {}
    for v in ms.victims:
        cols, obs = ms.of_victim(v)
        out[v] = fit_kernel(v, cols, obs, ms.isolated_times[v], ms.device,
                            cfg, fitted=out,
                            init=(inits or {}).get(v))
    return out


@dataclass
class FitReport:
    """JSON-able summary of a fit (bench_calib's currency)."""
    device: str
    victims: List[str]
    n_observations: int
    train_mse_log: float

    def to_json(self) -> Dict[str, object]:
        return {"device": self.device, "victims": self.victims,
                "n_observations": self.n_observations,
                "train_mse_log": self.train_mse_log}


def fit_report(ms: MeasurementSet,
               fitted: Mapping[str, KernelProfile]) -> FitReport:
    pred = predict_slowdowns(fitted, ms.colocations, ms.device)
    err = np.log(np.maximum(pred, 1e-9)) \
        - np.log(np.maximum(ms.slowdowns, 1e-9))
    return FitReport(ms.device.name, list(ms.victims), len(ms),
                     float(np.mean(err * err)))
