"""Hardware resource models.

The paper's central abstraction, one level deeper than a utilization
scalar: a device is a VECTOR of contendable resources. We ship the TPU
v5e model (the framework's target), plus H100 and RTX3090 models used to
validate the interference estimator against the paper's own measured
numbers (benchmarks/bench_*).

Resource vector axes (TPU naming; GPU models map their analogues):
  mxu     — matrix-unit FLOP/s           (GPU: tensor-core / fp pipelines)
  vpu     — vector-unit FLOP/s           (GPU: fma/alu pipelines)
  issue   — instruction-issue slots/s    (GPU: warp-scheduler IPC)
  hbm     — main-memory bandwidth B/s    (GPU: DRAM bandwidth)
  l2      — shared-cache bandwidth B/s   (GPU: L2; TPU: none -> CMEM/inf)
  smem    — on-chip scratch bandwidth B/s(GPU: shared mem; TPU: VMEM)
  smem_cap— on-chip capacity B           (GPU: L2/smem capacity; TPU: VMEM)
  ici     — interconnect B/s             (GPU: NVLink; TPU: ICI per chip)
  slots   — co-resident execution slots  (GPU: SM count; TPU: cores/chip)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict

import numpy as np

RESOURCE_AXES = ("mxu", "vpu", "issue", "hbm", "l2", "smem", "ici")
AXIS_INDEX = {r: i for i, r in enumerate(RESOURCE_AXES)}


@dataclass(frozen=True)
class DeviceModel:
    name: str
    mxu_flops: float            # peak matrix FLOP/s (bf16 / fp16-TC)
    vpu_flops: float            # peak vector FLOP/s (f32)
    issue_rate: float           # instructions/s device-wide
    hbm_bw: float               # B/s
    l2_bw: float                # B/s (aggregate)
    smem_bw: float              # B/s (aggregate on-chip scratch)
    ici_bw: float               # B/s per device off-chip interconnect
    hbm_capacity: float
    cache_capacity: float       # L2 (GPU) / VMEM (TPU) bytes
    n_slots: int                # SMs (GPU) / TensorCores (TPU)
    clock_hz: float

    def capacity(self, axis: str) -> float:
        return {
            "mxu": self.mxu_flops, "vpu": self.vpu_flops,
            "issue": self.issue_rate, "hbm": self.hbm_bw,
            "l2": self.l2_bw, "smem": self.smem_bw, "ici": self.ici_bw,
        }[axis]

    def capacity_vector(self) -> np.ndarray:
        """Per-axis capacities in RESOURCE_AXES order, floored at 1e-9 so
        division-by-capacity is always defined (e.g. ici_bw=0 models)."""
        return np.maximum(
            np.array([self.capacity(r) for r in RESOURCE_AXES], np.float64),
            1e-9)


# --------------------------------------------------------------------- #
#  TPU v5e — the deployment target                                       #
# --------------------------------------------------------------------- #
TPU_V5E = DeviceModel(
    name="tpu_v5e",
    mxu_flops=197e12,           # bf16
    vpu_flops=197e12 / 16,      # VPU is ~1/16 of MXU throughput
    issue_rate=0.94e9 * 8,      # VLIW bundles/s x slots (approx)
    hbm_bw=819e9,
    l2_bw=819e9,                # no transparent L2: alias HBM
    smem_bw=22e12,              # VMEM load+store aggregate (approx)
    ici_bw=50e9,                # per link; 16x16 torus: ~3 usable links
    hbm_capacity=16e9,
    cache_capacity=128e6,       # VMEM
    n_slots=1,                  # one TensorCore per chip (v5e)
    clock_hz=0.94e9,
)

# --------------------------------------------------------------------- #
#  TPU v5p — the training-class sibling: ~2.3x v5e on compute and        #
#  ~3.4x on HBM bandwidth, two TensorCores per chip, double the VMEM —   #
#  the second model of the heterogeneous fleet gates (a workload priced  #
#  on both sees genuinely different cache/bandwidth headroom)            #
# --------------------------------------------------------------------- #
TPU_V5P = DeviceModel(
    name="tpu_v5p",
    mxu_flops=459e12,           # bf16
    vpu_flops=459e12 / 16,
    issue_rate=1.75e9 * 8,
    hbm_bw=2765e9,
    l2_bw=2765e9,               # no transparent L2: alias HBM
    smem_bw=44e12,              # VMEM aggregate across both cores (approx)
    ici_bw=100e9,               # per link, 3D torus
    hbm_capacity=95e9,
    cache_capacity=256e6,       # VMEM aggregate (2 TensorCores)
    n_slots=2,                  # two TensorCores per chip (v5p)
    clock_hz=1.75e9,
)

# --------------------------------------------------------------------- #
#  NVIDIA H100 NVL — used to validate against the paper's measurements   #
# --------------------------------------------------------------------- #
H100 = DeviceModel(
    name="h100_nvl",
    mxu_flops=835e12,           # fp16 tensor core (no sparsity), NVL bin
    vpu_flops=60e12,            # fp32 CUDA cores (~2x for fp16 fma)
    issue_rate=132 * 4 * 1.785e9,  # 132 SMs x 4 warp-sched x clock
    hbm_bw=3.35e12,             # HBM3 (NVL 3.9e12; paper-era 3.35)
    l2_bw=7.0e12,               # approx aggregate L2 bandwidth
    smem_bw=132 * 128 * 4 * 1.785e9,  # 32 banks x 4B x clock x SMs
    ici_bw=450e9,               # NVLink4 per direction
    hbm_capacity=94e9,
    cache_capacity=50e6,        # 50MB L2 (paper §4.3)
    n_slots=132,
    clock_hz=1.785e9,
)
H100 = replace(H100, vpu_flops=66.9e12)

RTX3090 = DeviceModel(
    name="rtx3090",
    mxu_flops=142e12,           # fp16 TC
    vpu_flops=35.6e12,
    issue_rate=82 * 4 * 1.695e9,   # 82 SMs x 4 subpartitions (paper §4.4.2)
    hbm_bw=936e9,
    l2_bw=2.0e12,
    smem_bw=82 * 128 * 4 * 1.695e9,
    ici_bw=0.0,
    hbm_capacity=24e9,
    cache_capacity=6e6,
    n_slots=82,
    clock_hz=1.695e9,
)

DEVICES: Dict[str, DeviceModel] = {d.name: d for d in (TPU_V5E, TPU_V5P,
                                                       H100, RTX3090)}


def fp64_pipe(dev: DeviceModel) -> float:
    """FP64 pipeline (paper §4.4.3: half of FP32 rate on H100)."""
    return dev.vpu_flops / 2
