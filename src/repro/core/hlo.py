"""HLO text analysis — the framework's "NCU": per-instruction accounting
over the *post-SPMD-partitioning* module (``compiled.as_text()``).

XLA's built-in ``cost_analysis()`` counts each ``while`` body ONCE, which
under-reports scan-over-layers / microbatch-accumulation programs by the
trip count. This parser extracts trip counts from loop conditions and
multiplies, giving executed-FLOPs / executed-bytes / executed-collective
traffic — the numbers the roofline (§Roofline) and the interference
profiler (repro.core.profile) consume.

Capabilities:
  * symbol table: instruction -> (shape, dtype, bytes),
  * executed-multiplicity per computation (nested whiles multiply),
  * MXU flops (dot ops, contracting dims parsed), VPU element counts,
  * HBM traffic proxy: operand+result bytes at fusion boundaries,
  * collective traffic per kind with per-chip ICI byte estimates.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
    "u4": 1, "s4": 1, "token": 0, "opaque": 0,
}

_TYPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|"
    r"f8e4m3fn|f8e5m2|f8e4m3|c64|c128|u4|s4)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^(]*?\)?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\([^)]*\))?\s*->")
_SUBCOMP_KEYS = ("body", "condition", "to_apply", "calls",
                 "branch_computations", "called_computations")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all", "collective-broadcast")

# opcodes whose result/operands don't correspond to real memory traffic
_NO_TRAFFIC = {"parameter", "tuple", "get-tuple-element", "constant",
               "bitcast", "after-all", "iota", "while", "conditional",
               "call", "custom-call", "partition-id", "replica-id",
               "rng-get-and-update-state"}

_ELEMENTWISE_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt",
                               "power", "logistic", "sine", "cosine",
                               "exponential-minus-one", "log-plus-one"}


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _TYPE_RE.finditer(type_str):
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    shapes: List[Tuple[str, Tuple[int, ...]]]
    result_bytes: int
    operands: List[str] = field(default_factory=list)
    attrs: str = ""
    raw_args: str = ""


@dataclass
class Module:
    comps: Dict[str, List[Instr]]
    table: Dict[str, Instr]
    mult: Dict[str, float]              # executed multiplicity per comp
    fusion_bodies: set

    def executed(self):
        for cname, instrs in self.comps.items():
            m = self.mult.get(cname, 0.0)
            if m <= 0:
                continue
            for i in instrs:
                yield m, cname, i


_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def parse_module(text: str) -> Module:
    comps: Dict[str, List[Instr]] = {}
    order: List[str] = []
    cur: Optional[List[Instr]] = None
    for line in text.splitlines():
        # computation headers start at column 0 and end with '{'
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = _HDR_RE.match(line)
            if m:
                cur = []
                comps[m.group(1)] = cur
                order.append(m.group(1))
            continue
        mi = _INSTR_RE.match(line)
        if mi and cur is not None:
            name, type_str, opcode, rest = mi.groups()
            depth, buf = 1, []
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                buf.append(ch)
            ops_str = "".join(buf)
            attrs = rest[len(ops_str) + 1:]
            operands = re.findall(r"%([\w.\-]+)", ops_str)
            if not operands:  # un-%-prefixed form
                operands = [t.strip().split(" ")[-1] for t in ops_str.split(",")
                            if t.strip() and not t.strip()[0].isdigit()]
                operands = [o for o in operands if re.fullmatch(r"[\w.\-]+", o)]
            shapes = _parse_shapes(type_str)
            cur.append(Instr(name, opcode, shapes, _bytes_of(shapes),
                             operands, attrs, ops_str))

    table = {}
    for instrs in comps.values():
        for i in instrs:
            table[i.name] = i

    # --- multiplicities ---
    referenced = set()
    sub_refs: Dict[str, List[Tuple[str, str]]] = defaultdict(list)
    fusion_bodies = set()
    for cname, instrs in comps.items():
        for i in instrs:
            for key in _SUBCOMP_KEYS:
                for sub in re.findall(key + r"=\{?%?([\w.\-]+)", i.attrs or ""):
                    referenced.add(sub)
                    sub_refs[cname].append((i.opcode, sub))
                    if i.opcode == "fusion" and key == "calls":
                        fusion_bodies.add(sub)
            # while body/cond tracked with the instr for trip counts
    mult: Dict[str, float] = defaultdict(float)
    for n in comps:
        if n not in referenced:
            mult[n] = 1.0

    def trip_count(cond_name: str) -> int:
        best = 1
        names = [cond_name]
        for i in comps.get(cond_name, []):    # one level of called comps
            for key in _SUBCOMP_KEYS:
                names += re.findall(key + r"=\{?%?([\w.\-]+)", i.attrs or "")
        for n in names:
            for i in comps.get(n, []):
                if i.opcode == "constant":
                    m = re.fullmatch(r"\s*(\d+)\s*", i.raw_args or "")
                    if m:
                        best = max(best, int(m.group(1)))
        return best

    for _ in range(8):   # fixed point over nesting depth
        changed = False
        for cname, instrs in comps.items():
            m0 = mult.get(cname, 0.0)
            if m0 <= 0:
                continue
            for i in instrs:
                if i.opcode == "while":
                    body = _attr(i, "body")
                    cond = _attr(i, "condition")
                    t = trip_count(cond) if cond else 1
                    for sub, mm in ((body, m0 * t), (cond, m0 * (t + 1))):
                        if sub in comps and mult.get(sub, 0) < mm:
                            mult[sub] = mm
                            changed = True
                else:
                    for key in _SUBCOMP_KEYS:
                        for sub in re.findall(key + r"=\{?%?([\w.\-]+)",
                                              i.attrs or ""):
                            if sub in comps and mult.get(sub, 0) < m0:
                                mult[sub] = m0
                                changed = True
        if not changed:
            break
    return Module(comps, table, dict(mult), fusion_bodies)


def _attr(i: Instr, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", i.attrs or "")
    return m.group(1) if m else None


# --------------------------------------------------------------------- #
#  FLOPs                                                                 #
# --------------------------------------------------------------------- #
def _dot_flops(i: Instr, table: Dict[str, Instr]) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims)."""
    if not i.shapes:
        return 0.0
    res_elems = 1
    for d in i.shapes[0][1]:
        res_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", i.attrs or "")
    contract = 1
    if m and i.operands:
        lhs = table.get(i.operands[0])
        if lhs and lhs.shapes:
            dims = lhs.shapes[0][1]
            for idx in m.group(1).split(","):
                if idx != "" and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * res_elems * contract


@dataclass
class ModuleStats:
    mxu_flops: float = 0.0            # dot/conv flops (executed)
    vpu_elems: float = 0.0            # elementwise+reduce output elements
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0            # fusion-boundary operand+result bytes
    coll_bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_count_by_kind: Dict[str, int] = field(default_factory=dict)
    opcode_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes_by_kind.values())

    @property
    def vpu_flops(self) -> float:
        return self.vpu_elems  # ~1 flop per produced element (proxy)


def _traffic(kind: str, operand_bytes: float, result_bytes: float) -> float:
    if kind == "all-gather":
        return max(result_bytes - operand_bytes, 0.0)
    if kind in ("all-reduce", "collective-broadcast"):
        return 2.0 * result_bytes
    if kind == "reduce-scatter":
        return max(operand_bytes - result_bytes, 0.0)
    return operand_bytes


def analyze(text: str, fused: bool = None) -> ModuleStats:
    """fused=None autodetects: post-backend modules contain fusion ops and
    use the fusion-boundary traffic model; pre-fusion (after_spmd) modules
    use the materialized-tensor model (dots/reduces/slices count, pure
    elementwise chains assumed fused away — the TPU-optimistic proxy)."""
    mod = parse_module(text)
    if fused is None:
        fused = bool(mod.fusion_bodies)
    st = ModuleStats(coll_bytes_by_kind=defaultdict(float),
                     coll_count_by_kind=defaultdict(int),
                     opcode_bytes=defaultdict(float))
    for m, cname, i in mod.executed():
        base = i.opcode.replace("-start", "")
        if base in COLLECTIVES and not i.opcode.endswith("-done"):
            ob = sum(mod.table[o].result_bytes for o in i.operands
                     if o in mod.table)
            st.coll_bytes_by_kind[base] += m * _traffic(base, ob, i.result_bytes)
            st.coll_count_by_kind[base] += int(m)
        if i.opcode in ("dot", "convolution"):
            st.mxu_flops += m * _dot_flops(i, mod.table)
        elif i.opcode in _ELEMENTWISE_TRANSCENDENTAL:
            elems = i.result_bytes / max(_DTYPE_BYTES.get(i.shapes[0][0], 4), 1) \
                if i.shapes else 0
            st.transcendentals += m * elems
            st.vpu_elems += m * elems
        elif (i.opcode not in _NO_TRAFFIC and base not in COLLECTIVES
              and i.opcode not in ("fusion", "copy", "copy-start", "copy-done",
                                   "broadcast", "reshape", "transpose",
                                   "slice", "dynamic-slice",
                                   "dynamic-update-slice", "concatenate",
                                   "gather", "scatter", "pad", "convert")):
            if i.shapes:
                bpe = max(_DTYPE_BYTES.get(i.shapes[0][0], 4), 1)
                st.vpu_elems += m * (i.result_bytes / bpe)
        # HBM proxy. Slicing ops read only the sliced region (NOT the full
        # operand — scan bodies dynamic-slice stacked weights every
        # iteration; counting the full stack would inflate ~n_layers x).
        if (cname not in mod.fusion_bodies
                and i.opcode not in _NO_TRAFFIC
                and base not in COLLECTIVES):
            if i.opcode in ("dynamic-slice", "slice", "gather"):
                st.hbm_bytes += m * 2 * i.result_bytes
            elif i.opcode in ("dynamic-update-slice", "scatter"):
                upd = (mod.table[i.operands[1]].result_bytes
                       if len(i.operands) > 1 and i.operands[1] in mod.table
                       else i.result_bytes)
                st.hbm_bytes += m * 2 * upd
            elif i.opcode in ("dot", "convolution", "reduce", "sort"):
                ob = sum(mod.table[o].result_bytes for o in i.operands
                         if o in mod.table)
                st.hbm_bytes += m * (ob + i.result_bytes)
            elif not fused:
                # pre-fusion module: elementwise/convert/broadcast chains
                # are assumed fused away on TPU -> no standalone traffic
                pass
            elif i.opcode in ("broadcast", "iota"):
                st.hbm_bytes += m * i.result_bytes
            else:
                op_bytes = [mod.table[o].result_bytes for o in i.operands
                            if o in mod.table]
                ob = sum(op_bytes)
                total = ob + i.result_bytes
                # in-place update pattern (e.g. fused dynamic-update-slice
                # into a carried buffer): result aliases the big operand —
                # true traffic is the updated region, not the whole buffer
                if (i.opcode == "fusion" and op_bytes
                        and i.result_bytes == max(op_bytes)
                        and i.result_bytes > 4 * (total - 2 * i.result_bytes)
                        and total - 2 * i.result_bytes > 0):
                    total = 2 * (ob - i.result_bytes)
                st.hbm_bytes += m * total
        st.opcode_bytes[i.opcode] += m * i.result_bytes
    # dots inside fusion bodies: count their flops but their HHM traffic is
    # already covered by the enclosing fusion boundary.
    st.coll_bytes_by_kind = dict(st.coll_bytes_by_kind)
    st.coll_count_by_kind = dict(st.coll_count_by_kind)
    st.opcode_bytes = dict(st.opcode_bytes)
    return st


# Backwards-compatible helpers -------------------------------------------------
@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_stats(text: str) -> CollectiveStats:
    st = analyze(text)
    return CollectiveStats(st.coll_bytes_by_kind, st.coll_count_by_kind)


def opcode_histogram(text: str, weighted: bool = True) -> Dict[str, float]:
    return analyze(text).opcode_bytes
