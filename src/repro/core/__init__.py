"""The paper's contribution: multi-resource GPU/TPU interference
quantification and colocation scheduling. See DESIGN.md §1-2."""
from repro.core.backend import (SOLVER_BACKENDS, get_solver_backend,  # noqa: F401
                                set_solver_backend, solver_backend,
                                warmup_solver)
from repro.core.resources import (DEVICES, H100, RTX3090, TPU_V5E,  # noqa: F401
                                  TPU_V5P, DeviceModel)
from repro.core.profile import KernelProfile, ProfileMatrix, WorkloadProfile  # noqa: F401
from repro.core.scenario import (CompiledScenarios, Scenario,  # noqa: F401
                                 compile_scenarios, group_victim_scenarios)
from repro.core.estimator import (FRACTION_FLOOR, BatchResult,  # noqa: F401
                                  ColocationResult, colocation_speedup,
                                  estimate, estimate_batch,
                                  pairwise_slowdown, solve_scenarios,
                                  workload_slowdown)
from repro.core.fracsearch import (DENSE_SEARCH, LEGACY_SEARCH,  # noqa: F401
                                   FractionSearchConfig, GroupFractions,
                                   search_group_fractions,
                                   simplex_candidates)
from repro.core.sensitivity import (SensitivityReport, cache_pollution_curve,  # noqa: F401
                                    partition_curve, sensitivity,
                                    sensitivity_batch, stressor)
from repro.core.scheduler import (ColocationScheduler, Plan, Placement,  # noqa: F401
                                  evaluate_group, evaluate_group_partitioned,
                                  evaluate_pair, evaluate_pair_partitioned,
                                  plan_colocation)
from repro.core.repair import (RepairPlanner, RepairRecord,  # noqa: F401
                               RepairResult, RepairScope)
from repro.core.fleet import (BEST_EFFORT, SLO, AdmissionDecision,  # noqa: F401
                              FleetConfig, FleetPlan, FleetScheduler)
