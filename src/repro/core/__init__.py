"""The paper's contribution: multi-resource GPU/TPU interference
quantification and colocation scheduling. See DESIGN.md §1-2."""
from repro.core.resources import DEVICES, H100, RTX3090, TPU_V5E, DeviceModel  # noqa: F401
from repro.core.profile import KernelProfile, WorkloadProfile  # noqa: F401
from repro.core.estimator import (ColocationResult, colocation_speedup,  # noqa: F401
                                  estimate, pairwise_slowdown,
                                  workload_slowdown)
from repro.core.sensitivity import (SensitivityReport, cache_pollution_curve,  # noqa: F401
                                    sensitivity, stressor)
from repro.core.scheduler import Plan, Placement, evaluate_pair, plan_colocation  # noqa: F401
