"""Kernel / workload resource profiles — the paper's per-kernel NCU metric
vector, one level deeper than any single utilization scalar.

A ``KernelProfile`` records absolute demand per execution on every resource
axis (FLOPs, bytes, instructions); ``utilization(dev)`` converts to the
fraction of each axis consumed while the kernel runs at full speed, which
is what the interference estimator consumes.

Profiles come from three sources:
  * ``from_hlo_stats``: the dry-run's executed-HLO accounting (the "NCU
    for XLA" in repro.core.hlo) — real profiles of train/prefill/decode
    phases of every architecture;
  * ``analytic_*``: closed-form profiles of the microbenchmark stressors;
  * paper-reported NCU metrics (see benchmarks/) for validation.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.resources import RESOURCE_AXES, DeviceModel


@dataclass(frozen=True)
class KernelProfile:
    name: str
    demand: Dict[str, float]          # axis -> absolute work per execution
    duration: Optional[float] = None  # isolated wall-time; None => resource
                                      # bound (max of roofline terms). A
                                      # duration above every roofline term
                                      # models latency-/ILP-bound kernels
                                      # (paper: 24%-FP64-pipe kernel).
    cache_working_set: float = 0.0    # bytes in shared cache (L2/VMEM)
    cache_hit_fraction: float = 0.0   # fraction of hbm demand cacheable
    slots_needed: int = 0             # SMs/cores required (0 = flexible)
    duration_weight: float = 1.0      # relative time share inside workload

    def utilization(self, dev: DeviceModel,
                    cache_share: float = 1.0) -> Dict[str, float]:
        """Fraction of each axis consumed while running: u[r] =
        (d[r]/t)/C_r, with cache hits discounting HBM demand."""
        t = self.isolated_time(dev, cache_share)
        if t <= 0:
            return {r: 0.0 for r in RESOURCE_AXES}
        eff = self.effective_demand(dev, cache_share)
        return {r: (eff.get(r, 0.0) / t) / max(dev.capacity(r), 1e-9)
                for r in RESOURCE_AXES}

    def effective_demand(self, dev: DeviceModel,
                         cache_share: float = 1.0) -> Dict[str, float]:
        d = dict(self.demand)
        if self.cache_working_set > 0 and self.cache_hit_fraction > 0:
            resident = min(1.0, (dev.cache_capacity * cache_share)
                           / max(self.cache_working_set, 1.0))
            hit = self.cache_hit_fraction * resident
            d["hbm"] = d.get("hbm", 0.0) * (1.0 - hit)
            d["l2"] = max(d.get("l2", 0.0), self.demand.get("hbm", 0.0))
        return d

    def isolated_time(self, dev: DeviceModel,
                      cache_share: float = 1.0) -> float:
        eff = self.effective_demand(dev, cache_share)
        t = max((eff.get(r, 0.0) / max(dev.capacity(r), 1e-9))
                for r in RESOURCE_AXES)
        return max(t, self.duration or 0.0)

    def bottleneck(self, dev: DeviceModel) -> str:
        eff = self.effective_demand(dev)
        return max(RESOURCE_AXES,
                   key=lambda r: eff.get(r, 0.0) / max(dev.capacity(r), 1e-9))


@dataclass(frozen=True)
class WorkloadProfile:
    """A workload = weighted sequence of kernels/phases (per-kernel
    granularity is the paper's takeaway #1)."""
    name: str
    kernels: Tuple[KernelProfile, ...]
    slo_slowdown: float = 1.2          # max acceptable slowdown

    def total_time(self, dev: DeviceModel) -> float:
        return sum(k.isolated_time(dev) * k.duration_weight
                   for k in self.kernels)

    def mixed_utilization(self, dev: DeviceModel) -> Dict[str, float]:
        """Time-weighted average utilization vector."""
        tot = self.total_time(dev)
        u = {r: 0.0 for r in RESOURCE_AXES}
        for k in self.kernels:
            t = k.isolated_time(dev) * k.duration_weight
            ku = k.utilization(dev)
            for r in RESOURCE_AXES:
                u[r] += ku[r] * (t / max(tot, 1e-12))
        return u


# --------------------------------------------------------------------- #
#  Builders                                                              #
# --------------------------------------------------------------------- #
# instructions per unit of work on TPU: one MXU issue drives a 128x128x8
# systolic pass (~2.6e5 flops); one VPU issue drives 8x128 lanes x2 (fma)
_MXU_FLOPS_PER_ISSUE = 128 * 128 * 8 * 2
_VPU_FLOPS_PER_ISSUE = 8 * 128 * 2


def _issue_demand(mxu_flops: float, vpu_flops: float) -> float:
    return (mxu_flops / _MXU_FLOPS_PER_ISSUE
            + vpu_flops / _VPU_FLOPS_PER_ISSUE)


def from_hlo_stats(name: str, stats, n_devices: int = 1) -> KernelProfile:
    """Build a per-device phase profile from repro.core.hlo.ModuleStats."""
    return KernelProfile(
        name=name,
        demand={
            "mxu": stats.mxu_flops,
            "vpu": stats.vpu_flops,
            "issue": _issue_demand(stats.mxu_flops, stats.vpu_flops),
            "hbm": stats.hbm_bytes,
            "l2": stats.hbm_bytes,
            "smem": stats.mxu_flops / 9.0,     # MXU operand re-streaming
            "ici": stats.collective_bytes,
        })


def from_dryrun_json(rec: dict, name: Optional[str] = None) -> KernelProfile:
    h = rec["hlo_exec"]
    return KernelProfile(
        name=name or f"{rec['arch']}:{rec['shape']}",
        demand={
            "mxu": h["mxu_flops"],
            "vpu": h["vpu_flops"],
            "issue": _issue_demand(h["mxu_flops"], h["vpu_flops"]),
            "hbm": h["hbm_bytes"],
            "l2": h["hbm_bytes"],
            "smem": h["mxu_flops"] / 9.0,
            "ici": rec["collectives"]["total_bytes"],
        })


def analytic_matmul(name: str, m: int, n: int, k: int, dtype_bytes: int = 2,
                    iters: int = 1) -> KernelProfile:
    flops = 2.0 * m * n * k * iters
    bytes_ = (m * k + k * n + m * n) * dtype_bytes
    return KernelProfile(name, demand={
        "mxu": flops, "vpu": 0.0, "issue": flops / 256.0,
        "hbm": bytes_, "l2": bytes_, "smem": flops / 50.0, "ici": 0.0})


def analytic_copy(name: str, nbytes: float, passes: int = 1,
                  hit_fraction: float = 0.0) -> KernelProfile:
    b = 2.0 * nbytes * passes
    return KernelProfile(name, demand={
        "mxu": 0.0, "vpu": nbytes / 4 * passes, "issue": nbytes / 16 * passes,
        "hbm": b, "l2": b, "smem": 0.0, "ici": 0.0},
        cache_working_set=2.0 * nbytes, cache_hit_fraction=hit_fraction)


def analytic_vpu(name: str, elems: float, iters: int, ilp: int = 1) -> KernelProfile:
    flops = 2.0 * elems * iters * ilp
    return KernelProfile(name, demand={
        "mxu": 0.0, "vpu": flops, "issue": flops / 2.0,
        "hbm": elems * 8, "l2": elems * 8, "smem": 0.0, "ici": 0.0})
