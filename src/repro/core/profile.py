"""Kernel / workload resource profiles — the paper's per-kernel NCU metric
vector, one level deeper than any single utilization scalar.

A ``KernelProfile`` records absolute demand per execution on every resource
axis (FLOPs, bytes, instructions); ``utilization(dev)`` converts to the
fraction of each axis consumed while the kernel runs at full speed, which
is what the interference estimator consumes.

Profiles come from three sources:
  * ``from_hlo_stats``: the dry-run's executed-HLO accounting (the "NCU
    for XLA" in repro.core.hlo) — real profiles of train/prefill/decode
    phases of every architecture;
  * ``analytic_*``: closed-form profiles of the microbenchmark stressors;
  * paper-reported NCU metrics (see benchmarks/) for validation.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.resources import AXIS_INDEX, RESOURCE_AXES, DeviceModel

_HBM = AXIS_INDEX["hbm"]
_L2 = AXIS_INDEX["l2"]


@dataclass(frozen=True)
class KernelProfile:
    name: str
    demand: Dict[str, float]          # axis -> absolute work per execution
    duration: Optional[float] = None  # isolated wall-time; None => resource
                                      # bound (max of roofline terms). A
                                      # duration above every roofline term
                                      # models latency-/ILP-bound kernels
                                      # (paper: 24%-FP64-pipe kernel).
    cache_working_set: float = 0.0    # bytes in shared cache (L2/VMEM)
    cache_hit_fraction: float = 0.0   # fraction of hbm demand cacheable
    slots_needed: int = 0             # SMs/cores required (0 = flexible)
    duration_weight: float = 1.0      # relative time share inside workload

    def utilization(self, dev: DeviceModel,
                    cache_share: float = 1.0) -> Dict[str, float]:
        """Fraction of each axis consumed while running: u[r] =
        (d[r]/t)/C_r, with cache hits discounting HBM demand."""
        t = self.isolated_time(dev, cache_share)
        if t <= 0:
            return {r: 0.0 for r in RESOURCE_AXES}
        eff = self.effective_demand(dev, cache_share)
        return {r: (eff.get(r, 0.0) / t) / max(dev.capacity(r), 1e-9)
                for r in RESOURCE_AXES}

    def effective_demand(self, dev: DeviceModel,
                         cache_share: float = 1.0) -> Dict[str, float]:
        d = dict(self.demand)
        if self.cache_working_set > 0 and self.cache_hit_fraction > 0:
            resident = min(1.0, (dev.cache_capacity * cache_share)
                           / max(self.cache_working_set, 1.0))
            hit = self.cache_hit_fraction * resident
            d["hbm"] = d.get("hbm", 0.0) * (1.0 - hit)
            d["l2"] = max(d.get("l2", 0.0), self.demand.get("hbm", 0.0))
        return d

    def isolated_time(self, dev: DeviceModel,
                      cache_share: float = 1.0) -> float:
        eff = self.effective_demand(dev, cache_share)
        t = max((eff.get(r, 0.0) / max(dev.capacity(r), 1e-9))
                for r in RESOURCE_AXES)
        return max(t, self.duration or 0.0)

    def bottleneck(self, dev: DeviceModel) -> str:
        eff = self.effective_demand(dev)
        return max(RESOURCE_AXES,
                   key=lambda r: eff.get(r, 0.0) / max(dev.capacity(r), 1e-9))


@dataclass(frozen=True)
class WorkloadProfile:
    """A workload = weighted sequence of kernels/phases (per-kernel
    granularity is the paper's takeaway #1)."""
    name: str
    kernels: Tuple[KernelProfile, ...]
    slo_slowdown: float = 1.2          # max acceptable slowdown

    def total_time(self, dev: DeviceModel) -> float:
        return sum(k.isolated_time(dev) * k.duration_weight
                   for k in self.kernels)

    def mixed_utilization(self, dev: DeviceModel) -> Dict[str, float]:
        """Time-weighted average utilization vector."""
        tot = self.total_time(dev)
        u = {r: 0.0 for r in RESOURCE_AXES}
        for k in self.kernels:
            t = k.isolated_time(dev) * k.duration_weight
            ku = k.utilization(dev)
            for r in RESOURCE_AXES:
                u[r] += ku[r] * (t / max(tot, 1e-12))
        return u

    def representative_kernel(self, dev: DeviceModel) -> KernelProfile:
        """Time-weighted aggregate kernel, named after the workload: the
        steady-background stand-in the scheduler prices co-runners
        against (and the slot-fraction anchor — fraction dicts keyed by
        the workload name bind to this kernel)."""
        u = self.mixed_utilization(dev)
        t = self.total_time(dev)
        return KernelProfile(self.name, demand={
            r: u[r] * dev.capacity(r) * t for r in u})


# --------------------------------------------------------------------- #
#  ProfileMatrix — dense (kernels x axes) compilation of KernelProfiles  #
# --------------------------------------------------------------------- #
# The batch estimator's input format: every per-kernel scalar/dict of
# KernelProfile becomes one dense float64 array, so the cache model,
# roofline times, and utilizations of ANY number of kernels are single
# NumPy expressions. The three helpers below are the vectorized twins of
# KernelProfile.effective_demand / isolated_time / utilization and accept
# arbitrary leading batch shape (..., K) / (..., K, A).

def effective_demand_arrays(demand: np.ndarray, ws: np.ndarray,
                            hit: np.ndarray, cache_capacity: float,
                            cache_share) -> np.ndarray:
    """Vectorized KernelProfile.effective_demand: cache hits discount HBM
    traffic; the absorbed stream reappears as L2 bandwidth demand."""
    d = np.array(demand, np.float64, copy=True)
    cached = (ws > 0) & (hit > 0)
    resident = np.minimum(1.0, (cache_capacity * np.asarray(cache_share))
                          / np.maximum(ws, 1.0))
    hit_f = hit * resident
    d[..., _HBM] = np.where(cached, demand[..., _HBM] * (1.0 - hit_f),
                            demand[..., _HBM])
    d[..., _L2] = np.where(cached,
                           np.maximum(demand[..., _L2], demand[..., _HBM]),
                           demand[..., _L2])
    return d


def isolated_time_arrays(eff: np.ndarray, duration: np.ndarray,
                         cap_vec: np.ndarray) -> np.ndarray:
    """Vectorized KernelProfile.isolated_time: roofline max over axes,
    floored by the latency-bound duration."""
    return np.maximum((eff / cap_vec).max(-1), duration)


def utilization_arrays(eff: np.ndarray, t: np.ndarray,
                       cap_vec: np.ndarray) -> np.ndarray:
    """Vectorized KernelProfile.utilization: u = (d/t)/C, zero for t<=0."""
    with np.errstate(divide="ignore", invalid="ignore"):
        u = (eff / t[..., None]) / cap_vec
    return np.where(t[..., None] > 0, u, 0.0)


@dataclass(frozen=True)
class ProfileMatrix:
    """KernelProfiles compiled once into dense arrays (one row per kernel).

    demand is (K, A) in RESOURCE_AXES order; duration/ws/hit/slots are
    (K,). Rows are addressed by position; ``index`` maps names to rows.
    """
    names: Tuple[str, ...]
    demand: np.ndarray
    duration: np.ndarray
    cache_working_set: np.ndarray
    cache_hit_fraction: np.ndarray
    slots_needed: np.ndarray

    @classmethod
    def from_profiles(cls, profiles: Sequence[KernelProfile]) -> "ProfileMatrix":
        ks = list(profiles)
        demand = np.zeros((len(ks), len(RESOURCE_AXES)), np.float64)
        for i, k in enumerate(ks):
            for r, a in AXIS_INDEX.items():
                demand[i, a] = k.demand.get(r, 0.0)
        return cls(
            names=tuple(k.name for k in ks),
            demand=demand,
            duration=np.array([k.duration or 0.0 for k in ks], np.float64),
            cache_working_set=np.array([k.cache_working_set for k in ks],
                                       np.float64),
            cache_hit_fraction=np.array([k.cache_hit_fraction for k in ks],
                                        np.float64),
            slots_needed=np.array([k.slots_needed for k in ks], np.float64),
        )

    @classmethod
    def from_arrays(cls, names: Sequence[str], demand: np.ndarray,
                    duration=None, cache_working_set=None,
                    cache_hit_fraction=None, slots_needed=None
                    ) -> "ProfileMatrix":
        """Build directly from dense arrays (analytic consumers: the serve
        engine's chunk candidates, the sensitivity stressor grids)."""
        n = len(names)

        def _vec(x):
            if x is None:
                return np.zeros(n, np.float64)
            return np.broadcast_to(np.asarray(x, np.float64), (n,)).copy()

        return cls(tuple(names), np.asarray(demand, np.float64),
                   _vec(duration), _vec(cache_working_set),
                   _vec(cache_hit_fraction), _vec(slots_needed))

    def __len__(self) -> int:
        return len(self.names)

    @property
    def index(self) -> Dict[str, int]:
        return {n: i for i, n in enumerate(self.names)}

    def effective_demand(self, dev: DeviceModel, cache_share=1.0) -> np.ndarray:
        share = np.broadcast_to(np.asarray(cache_share, np.float64),
                                self.duration.shape)
        return effective_demand_arrays(self.demand, self.cache_working_set,
                                       self.cache_hit_fraction,
                                       dev.cache_capacity, share)

    def isolated_time(self, dev: DeviceModel, cache_share=1.0) -> np.ndarray:
        return isolated_time_arrays(self.effective_demand(dev, cache_share),
                                    self.duration, dev.capacity_vector())

    def utilization(self, dev: DeviceModel, cache_share=1.0) -> np.ndarray:
        eff = self.effective_demand(dev, cache_share)
        t = isolated_time_arrays(eff, self.duration, dev.capacity_vector())
        return utilization_arrays(eff, t, dev.capacity_vector())

    def profile(self, i: int) -> KernelProfile:
        """Row back to a KernelProfile (debugging / interop)."""
        return KernelProfile(
            self.names[i],
            demand={r: float(self.demand[i, a])
                    for r, a in AXIS_INDEX.items()},
            duration=float(self.duration[i]) or None,
            cache_working_set=float(self.cache_working_set[i]),
            cache_hit_fraction=float(self.cache_hit_fraction[i]),
            slots_needed=int(self.slots_needed[i]))


# --------------------------------------------------------------------- #
#  Builders                                                              #
# --------------------------------------------------------------------- #
# instructions per unit of work on TPU: one MXU issue drives a 128x128x8
# systolic pass (~2.6e5 flops); one VPU issue drives 8x128 lanes x2 (fma)
_MXU_FLOPS_PER_ISSUE = 128 * 128 * 8 * 2
_VPU_FLOPS_PER_ISSUE = 8 * 128 * 2


def _issue_demand(mxu_flops: float, vpu_flops: float) -> float:
    return (mxu_flops / _MXU_FLOPS_PER_ISSUE
            + vpu_flops / _VPU_FLOPS_PER_ISSUE)


def from_hlo_stats(name: str, stats, n_devices: int = 1) -> KernelProfile:
    """Build a per-device phase profile from repro.core.hlo.ModuleStats."""
    return KernelProfile(
        name=name,
        demand={
            "mxu": stats.mxu_flops,
            "vpu": stats.vpu_flops,
            "issue": _issue_demand(stats.mxu_flops, stats.vpu_flops),
            "hbm": stats.hbm_bytes,
            "l2": stats.hbm_bytes,
            "smem": stats.mxu_flops / 9.0,     # MXU operand re-streaming
            "ici": stats.collective_bytes,
        })


def from_dryrun_json(rec: dict, name: Optional[str] = None) -> KernelProfile:
    h = rec["hlo_exec"]
    return KernelProfile(
        name=name or f"{rec['arch']}:{rec['shape']}",
        demand={
            "mxu": h["mxu_flops"],
            "vpu": h["vpu_flops"],
            "issue": _issue_demand(h["mxu_flops"], h["vpu_flops"]),
            "hbm": h["hbm_bytes"],
            "l2": h["hbm_bytes"],
            "smem": h["mxu_flops"] / 9.0,
            "ici": rec["collectives"]["total_bytes"],
        })


def analytic_matmul(name: str, m: int, n: int, k: int, dtype_bytes: int = 2,
                    iters: int = 1) -> KernelProfile:
    flops = 2.0 * m * n * k * iters
    bytes_ = (m * k + k * n + m * n) * dtype_bytes
    return KernelProfile(name, demand={
        "mxu": flops, "vpu": 0.0, "issue": flops / 256.0,
        "hbm": bytes_, "l2": bytes_, "smem": flops / 50.0, "ici": 0.0})


def analytic_copy(name: str, nbytes: float, passes: int = 1,
                  hit_fraction: float = 0.0) -> KernelProfile:
    b = 2.0 * nbytes * passes
    return KernelProfile(name, demand={
        "mxu": 0.0, "vpu": nbytes / 4 * passes, "issue": nbytes / 16 * passes,
        "hbm": b, "l2": b, "smem": 0.0, "ici": 0.0},
        cache_working_set=2.0 * nbytes, cache_hit_fraction=hit_fraction)


def analytic_vpu(name: str, elems: float, iters: int, ilp: int = 1) -> KernelProfile:
    flops = 2.0 * elems * iters * ilp
    return KernelProfile(name, demand={
        "mxu": 0.0, "vpu": flops, "issue": flops / 2.0,
        "hbm": elems * 8, "l2": elems * 8, "smem": 0.0, "ici": 0.0})
