"""Fault-tolerant fleet scheduling over the colocation core.

The ``ColocationScheduler`` plans ONE device; production means a fleet:
admission control, priority classes, preemption, and — the part a happy
path never exercises — surviving device failure.  ``FleetScheduler``
owns a set of ``DeviceModel``-backed devices, each wrapping its own
``ColocationScheduler`` (the residency tracker with drain/snapshot
hooks), and keeps the whole system live through faults:

  * **Admission control** — arrivals are SLO or best-effort; every
    outcome (placed / queued / rejected / evicted / migrated / degraded)
    is an explicit ``AdmissionDecision`` in ``decisions``.  Unplaced
    workloads wait in bounded per-class queues; beyond
    ``FleetConfig.queue_limit`` an arrival is REJECTED with a record,
    never silently grown.
  * **Preemption** — placement replays SLO workloads before best-effort,
    so an SLO arrival that cannot otherwise fit displaces best-effort
    work (each eviction recorded); evicted workloads stay tracked and
    re-place the moment capacity returns.
  * **Failure handling** — the ``repro.ft`` primitives are wired into
    the event loop: a device that misses its heartbeat
    (``HeartbeatTracker`` on an injectable monotonic clock) is declared
    dead, its ``ColocationScheduler`` drains, and its workloads re-place
    on the survivors; a straggling device (``StragglerMonitor`` EWMA)
    degrades — SLO work migrates off, best-effort may stay; training
    workloads that lose chips get a ``plan_rescale`` elastic-rescale
    plan attached to their record.  Placement retries back off
    exponentially; a workload the surviving fleet genuinely cannot hold
    lands in a final "degraded" state — tracked, reported, retried when
    capacity changes, never dropped and never a crash (``tick`` seals
    the event loop: internal failures become ``action="error"``
    decisions, not exceptions).

**Determinism / the recovery gate.**  The mapping of admitted workloads
to devices is recomputed by a deterministic replay — priority classes
in order, arrival order within a class, each workload taking the
max-gain feasible device (earliest device on ties) — over a fleet-level
price cache keyed ``(device model, member uids)``.  Pricing is batched
per replay step and DEDUPLICATED across devices and events by that
cache (two empty v5e devices price a candidate group once, and a
migration re-prices only groups never seen before).  Because the replay
is a pure function of (tracked pool, live devices, prices), the online
fleet state after any fault trace equals a cold ``FleetScheduler`` plan
over the surviving devices and workloads — the recovery gate
``benchmarks/bench_fleet.py`` enforces at 1e-9.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)

from repro.core.estimator import solve_scenarios
from repro.core.fracsearch import (FractionSearchConfig, group_metrics,
                                   member_slowdowns, search_group_fractions)
from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import DeviceModel
from repro.core.scenario import group_victim_scenarios
from repro.core.scheduler import ColocationScheduler, Placement
from repro.ft import (HeartbeatTracker, RescalePlan, StragglerMonitor,
                      plan_rescale)

# priority classes (admission order: SLO replays before best-effort)
SLO = "slo"
BEST_EFFORT = "best_effort"
_PRIORITY_RANK = {SLO: 0, BEST_EFFORT: 1}

# workload lifecycle states
PLACED = "placed"
QUEUED = "queued"
DEGRADED = "degraded"          # final: capacity genuinely insufficient

# device lifecycle states
D_HEALTHY = "healthy"
D_DEGRADED = "degraded"        # straggling: best-effort only
D_DEAD = "dead"


@dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs (per-device colocation inherits the core's).

    max_group_size: colocation capacity of one device (workloads that
        must share it feasibly — the per-device ``ColocationScheduler``
        limit).
    queue_limit: bounded admission queue PER priority class; arrivals
        beyond it are rejected with a decision record.
    heartbeat_timeout: virtual seconds without a beat before a device is
        declared dead and drained.
    max_retries / backoff_base: placement retries for queued workloads
        back off as ``backoff_base * 2**retries``; after ``max_retries``
        failed due-retries the workload enters the final "degraded"
        state (still tracked, re-attempted on capacity changes).
    allow_partition / fraction_search: forwarded to group pricing — an
        SLO-violating candidate group falls back to the k-way
        slot-fraction search exactly like the single-device scheduler.
    straggler_factor / straggler_warmup: per-device ``StragglerMonitor``
        EWMA detection knobs.
    """
    max_group_size: int = 3
    queue_limit: int = 16
    heartbeat_timeout: float = 5.0
    max_retries: int = 3
    backoff_base: float = 1.0
    allow_partition: bool = True
    fraction_search: Optional[FractionSearchConfig] = None
    straggler_factor: float = 3.0
    straggler_warmup: int = 3


@dataclass(frozen=True)
class AdmissionDecision:
    """One audit-log entry: what the fleet decided, when, and why."""
    seq: int
    time: float
    action: str                 # placed|queued|rejected|evicted|displaced|
                                # migrated|retry-failed|degraded|removed|
                                # device-dead|device-degraded|
                                # device-recovered|rescale-planned|error
    workload: Optional[str] = None
    priority: Optional[str] = None
    device: Optional[str] = None
    reason: str = ""

    def __repr__(self):
        who = self.workload or self.device or "-"
        return (f"<#{self.seq} t={self.time:.2f} {self.action} {who}"
                f"{' @' + self.device if self.workload and self.device else ''}"
                f" ({self.reason})>")


@dataclass
class _Tracked:
    """Internal per-workload record (arrival order = dict order).

    ``uid`` bumps on every (re)submit — it versions the price cache;
    ``pos`` is the stable arrival position — it orders replay and
    canonical group membership, so a resubmitted workload keeps its
    place (and an online trace keeps matching the cold replay)."""
    profile: WorkloadProfile
    priority: str
    uid: int
    pos: int = 0
    state: str = QUEUED
    device: Optional[str] = None
    retries: int = 0
    next_retry: float = 0.0
    train_meta: Optional[dict] = None    # mesh_shape/global_batch/... for
    rescale: Optional[RescalePlan] = None  # plan_rescale on chip loss


@dataclass
class FleetDevice:
    """One device: a DeviceModel wrapping its own ColocationScheduler."""
    device_id: str
    model: DeviceModel
    sched: ColocationScheduler
    monitor: StragglerMonitor
    chips: int = 1
    state: str = D_HEALTHY
    resident_uids: Dict[str, int] = field(default_factory=dict)


@dataclass
class FleetPlan:
    """The fleet's current placement state (see ``FleetScheduler.plan``)."""
    placements: Dict[str, Placement]     # device_id -> its colocation group
    queued: List[str]                    # admitted, waiting for capacity
    degraded: List[str]                  # final state: capacity insufficient
    device_states: Dict[str, str]

    @property
    def placed(self) -> Dict[str, str]:
        """workload name -> device_id."""
        return {n: did for did, p in self.placements.items()
                for n in p.workloads}

    def placement_rate(self, names: Iterable[str]) -> float:
        """Fraction of ``names`` currently placed (1.0 for an empty set)."""
        names = list(names)
        if not names:
            return 1.0
        placed = self.placed
        return sum(n in placed for n in names) / len(names)


# fleet price record: (gain, meets_slo, slowdowns by name, fractions by name)
_Price = Tuple[float, bool, Dict[str, float], Dict[str, float]]


class FleetScheduler:
    """Admission control + placement + fault recovery over many devices.

    >>> clock = FakeClock()                      # repro.ft.inject
    >>> fleet = FleetScheduler({"dev0": TPU_V5E, "dev1": TPU_V5E},
    ...                        clock=clock)
    >>> fleet.submit(decode, priority=SLO)       # -> AdmissionDecision
    >>> fleet.heartbeat("dev0"); fleet.tick()    # the event loop
    >>> fleet.plan()                             # -> FleetPlan

    ``submit``/``remove`` raise on caller errors (unknown names, bad
    priority) exactly like ``ColocationScheduler``; the event-loop
    surface (``tick``, ``observe_step`` internals, replanning) never
    raises — failures become ``action="error"`` decisions.
    """

    def __init__(self, devices: Mapping[str, DeviceModel] | Iterable[Tuple[str, DeviceModel]],
                 config: Optional[FleetConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = config or FleetConfig()
        self.clock = clock
        self.search = (self.cfg.fraction_search
                       or FractionSearchConfig.default())
        self.devices: Dict[str, FleetDevice] = {}
        self.heartbeats = HeartbeatTracker(
            timeout_s=self.cfg.heartbeat_timeout, clock=clock)
        self._tracked: Dict[str, _Tracked] = {}      # arrival order
        self._next_uid = 0
        self._next_pos = 0
        self._seq = 0
        self.decisions: List[AdmissionDecision] = []
        self._price_cache: Dict[Tuple[str, Tuple[int, ...]], _Price] = {}
        self._reps: Dict[Tuple[int, str], KernelProfile] = {}
        self._assignment: Dict[str, str] = {}        # name -> device_id
        self._groups: Dict[str, List[_Tracked]] = {}  # device_id -> members
        self._info: Dict[str, _Price] = {}           # device_id -> group price
        self.stats: Dict[str, int] = {
            "arrivals": 0, "departures": 0, "rejected": 0, "evicted": 0,
            "migrated": 0, "displaced": 0, "retries": 0, "device_deaths": 0,
            "replans": 0, "scenarios_solved": 0, "groups_priced": 0,
            "errors": 0,
        }
        items = devices.items() if isinstance(devices, Mapping) else devices
        for did, model in items:
            self.add_device(did, model)

    # ----------------------------- devices ------------------------ #
    def add_device(self, device_id: str, model: DeviceModel,
                   chips: int = 1) -> None:
        """Register a device; its heartbeat clock starts NOW (a device
        that never beats is declared dead after the timeout)."""
        if device_id in self.devices:
            raise ValueError(f"duplicate device: {device_id!r}")
        self.devices[device_id] = FleetDevice(
            device_id, model,
            ColocationScheduler(model,
                                max_group_size=self.cfg.max_group_size,
                                allow_partition=self.cfg.allow_partition,
                                fraction_search=self.search),
            StragglerMonitor(factor=self.cfg.straggler_factor,
                             warmup=self.cfg.straggler_warmup,
                             clock=self.clock),
            chips=chips)
        self.heartbeats.beat(device_id)
        if self._tracked:
            # new capacity: queued/degraded workloads get another shot
            self._replan(f"device {device_id} added")

    def heartbeat(self, device_id: str, now: Optional[float] = None) -> None:
        """A device host reports in.  A beat from a dead device revives
        it (the host came back): healthy again, capacity replanned."""
        dev = self.devices.get(device_id)
        if dev is None:
            raise KeyError(f"unknown device: {device_id!r}")
        self.heartbeats.beat(device_id, now)
        if dev.state == D_DEAD:
            dev.state = D_HEALTHY
            self._decide("device-recovered", device=device_id,
                         reason="heartbeat resumed")
            self._replan(f"device {device_id} recovered")

    def revive_device(self, device_id: str) -> None:
        """Operator override: clear a device's degraded (straggler) state."""
        dev = self.devices[device_id]
        if dev.state == D_DEGRADED:
            dev.state = D_HEALTHY
            dev.monitor.ewma = None
            dev.monitor.n = 0
            self._decide("device-recovered", device=device_id,
                         reason="straggle cleared")
            self._replan(f"device {device_id} revived")

    def decommission(self, device_id: str) -> None:
        """Planned removal: drain the device and re-place its workloads
        (same migration path as a failure, minus the timeout wait)."""
        dev = self.devices.get(device_id)
        if dev is None:
            raise KeyError(f"unknown device: {device_id!r}")
        if dev.state == D_DEAD:
            return                      # documented no-op: already drained
        self._mark_dead(dev, reason="decommissioned")
        self._replan(f"device {device_id} decommissioned")

    def observe_step(self, device_id: str, step: int, dt: float) -> bool:
        """Feed one step-time observation to the device's straggler
        monitor; EWMA detection degrades the device (SLO work migrates
        off at the next replan, best-effort may remain)."""
        dev = self.devices.get(device_id)
        if dev is None:
            raise KeyError(f"unknown device: {device_id!r}")
        try:
            straggling = dev.monitor.observe(step, dt)
            if straggling and dev.state == D_HEALTHY:
                dev.state = D_DEGRADED
                self._decide("device-degraded", device=device_id,
                             reason=f"straggling: dt={dt:.3g} vs "
                                    f"ewma={dev.monitor.ewma:.3g}")
                self._replan(f"device {device_id} degraded")
            return straggling
        except Exception as e:      # pragma: no cover - defensive seal
            self._error(f"observe_step({device_id}): {e!r}")
            return False

    # ----------------------------- workloads ----------------------- #
    def __len__(self) -> int:
        return len(self._tracked)

    def __contains__(self, name: str) -> bool:
        return name in self._tracked

    @property
    def workloads(self) -> List[Tuple[WorkloadProfile, str]]:
        """(profile, priority) pairs in arrival order — exactly what a
        cold fleet over the survivors must be fed to reproduce the
        online plan (the recovery gate's contract)."""
        return [(t.profile, t.priority) for t in self._tracked.values()]

    def workload_state(self, name: str) -> Dict:
        t = self._tracked[name]
        return {"state": t.state, "device": t.device, "priority": t.priority,
                "retries": t.retries, "next_retry": t.next_retry,
                "rescale": t.rescale}

    def submit(self, workload: WorkloadProfile, priority: str = SLO,
               train_meta: Optional[dict] = None) -> AdmissionDecision:
        """Admit a workload and decide its fate NOW: returns the
        decision record (placed / queued / rejected).  Re-submitting an
        existing name replaces its profile and priority but keeps its
        arrival position (the core scheduler's last-profile-wins rule);
        its cached prices are invalidated.

        ``train_meta`` (optional) marks an elastic training job:
        ``{"mesh_shape": {...}, "global_batch": int,
        "num_microbatches": int, "step": int}`` — if its device later
        dies, a ``plan_rescale`` recovery plan is attached to the
        workload record and surfaced as a "rescale-planned" decision.
        """
        if priority not in _PRIORITY_RANK:
            raise ValueError(f"priority must be {SLO!r} or {BEST_EFFORT!r},"
                             f" got {priority!r}")
        name = workload.name
        old = self._tracked.get(name)
        if old is not None:
            self._drop_prices(old.uid)
            old.profile = workload
            old.priority = priority
            old.uid = self._next_uid
            old.train_meta = train_meta if train_meta else old.train_meta
            t = old
        else:
            t = self._tracked[name] = _Tracked(workload, priority,
                                               self._next_uid,
                                               pos=self._next_pos,
                                               train_meta=train_meta)
            self._next_pos += 1
        self._next_uid += 1
        self.stats["arrivals"] += 1
        n0 = len(self.decisions)
        self._replan(f"arrival {name}")
        if t.state == PLACED:
            for d in self.decisions[n0:]:
                if d.workload == name and d.action in ("placed", "migrated"):
                    return d
            return self._decide("placed", t, device=t.device,
                                reason=f"arrival {name} (placement unchanged)")
        # not placeable now: bounded queue or explicit rejection
        backlog = sum(1 for o in self._tracked.values()
                      if o.state in (QUEUED, DEGRADED)
                      and o.priority == priority)
        if backlog > self.cfg.queue_limit:
            del self._tracked[name]
            self._drop_prices(t.uid)
            self.stats["rejected"] += 1
            return self._decide(
                "rejected", t,
                reason=f"{priority} queue full "
                       f"({self.cfg.queue_limit} waiting)")
        t.next_retry = self.clock() + self.cfg.backoff_base
        return self._decide("queued", t,
                            reason=f"no feasible device; retry in "
                                   f"{self.cfg.backoff_base:.1f}s")

    def submit_many(self, arrivals: Sequence) -> List[AdmissionDecision]:
        """Admit a same-tick arrival storm in ONE deduplicated replay.

        ``arrivals`` holds ``(workload, priority)`` or ``(workload,
        priority, train_meta)`` tuples.  Semantically equivalent to
        calling ``submit`` per item — queued workloads never occupy a
        device, so registering every arrival first and replanning once
        yields the same final placements and the same bounded-queue
        admission outcomes — but it costs one replay (and one round of
        group pricing) instead of one per arrival.  Duplicate names in
        the batch collapse to the last profile (last-profile-wins, as
        with re-submission).  Returns one decision per distinct name in
        first-submission order.
        """
        items = []
        for entry in arrivals:
            workload, priority = entry[0], entry[1]
            train_meta = entry[2] if len(entry) > 2 else None
            if priority not in _PRIORITY_RANK:
                raise ValueError(f"priority must be {SLO!r} or "
                                 f"{BEST_EFFORT!r}, got {priority!r}")
            items.append((workload, priority, train_meta))
        if not items:
            return []
        order: List[str] = []
        for workload, priority, train_meta in items:
            name = workload.name
            old = self._tracked.get(name)
            if old is not None:
                self._drop_prices(old.uid)
                old.profile = workload
                old.priority = priority
                old.uid = self._next_uid
                old.train_meta = train_meta if train_meta else old.train_meta
            else:
                self._tracked[name] = _Tracked(workload, priority,
                                               self._next_uid,
                                               pos=self._next_pos,
                                               train_meta=train_meta)
                self._next_pos += 1
            self._next_uid += 1
            self.stats["arrivals"] += 1
            if name not in order:
                order.append(name)
        n0 = len(self.decisions)
        self._replan(f"arrival storm ({len(order)} workloads)")
        batch = set(order)
        placed_dec: Dict[str, AdmissionDecision] = {}
        for d in self.decisions[n0:]:
            if d.workload in batch and d.action in ("placed", "migrated"):
                placed_dec.setdefault(d.workload, d)
        out: List[AdmissionDecision] = []
        for name in order:
            t = self._tracked[name]
            if t.state == PLACED:
                d = placed_dec.get(name)
                out.append(d if d is not None else self._decide(
                    "placed", t, device=t.device,
                    reason=f"arrival {name} (placement unchanged)"))
                continue
            backlog = sum(1 for o in self._tracked.values()
                          if o.state in (QUEUED, DEGRADED)
                          and o.priority == t.priority)
            if backlog > self.cfg.queue_limit:
                del self._tracked[name]
                self._drop_prices(t.uid)
                self.stats["rejected"] += 1
                out.append(self._decide(
                    "rejected", t,
                    reason=f"{t.priority} queue full "
                           f"({self.cfg.queue_limit} waiting)"))
                continue
            t.next_retry = self.clock() + self.cfg.backoff_base
            out.append(self._decide(
                "queued", t,
                reason=f"no feasible device; retry in "
                       f"{self.cfg.backoff_base:.1f}s"))
        return out

    def remove(self, name: str) -> None:
        """A workload departs.  Unknown names raise ``KeyError`` before
        any state is touched (mirrors ``ColocationScheduler.remove``)."""
        t = self._tracked.get(name)
        if t is None:
            raise KeyError(f"unknown workload: {name!r}")
        del self._tracked[name]
        self._drop_prices(t.uid)
        self._assignment.pop(name, None)
        self.stats["departures"] += 1
        self._decide("removed", t, device=t.device, reason="departure")
        self._replan(f"departure {name}")

    # ----------------------------- event loop ---------------------- #
    def tick(self, now: Optional[float] = None) -> None:
        """One controller iteration: scan heartbeats (missed ->
        dead + drain), fire due placement retries.  NEVER raises —
        internal failures become ``action="error"`` decisions."""
        try:
            now = self.clock() if now is None else now
            dead = [w for w in self.heartbeats.dead_workers(now)
                    if w in self.devices
                    and self.devices[w].state != D_DEAD]
            for did in dead:
                self._mark_dead(self.devices[did],
                                reason=f"missed heartbeat for "
                                       f">{self.cfg.heartbeat_timeout:.1f}s")
            retry_due = frozenset(
                n for n, t in self._tracked.items()
                if t.state == QUEUED and t.next_retry <= now)
            if dead:
                self._replan("device failure: " + ", ".join(dead),
                             retry_due=retry_due)
            elif retry_due:
                self._replan("retry " + ", ".join(sorted(retry_due)),
                             retry_due=retry_due)
        except Exception as e:
            self._error(f"tick: {e!r}")

    @property
    def degraded(self) -> bool:
        """True when the fleet is running in degraded mode: a device is
        dead/straggling or a workload cannot be placed on the survivors."""
        return (any(d.state != D_HEALTHY for d in self.devices.values())
                or any(t.state == DEGRADED for t in self._tracked.values()))

    # ----------------------------- placement ----------------------- #
    def _live(self, priority: str) -> List[FleetDevice]:
        """Devices this priority class may use, in registry order: SLO
        only healthy; best-effort also degraded (slow) devices."""
        ok = (D_HEALTHY,) if priority == SLO else (D_HEALTHY, D_DEGRADED)
        return [d for d in self.devices.values() if d.state in ok]

    def _replay(self):
        """The deterministic assignment: priority classes in order,
        arrival order within a class, each workload placed on the
        max-gain feasible device (earliest on ties) or left unplaced.
        Pure function of (tracked pool, device states, prices)."""
        assign: Dict[str, List[_Tracked]] = {
            d.device_id: [] for d in self.devices.values()
            if d.state != D_DEAD}
        info: Dict[str, _Price] = {}
        unplaced: List[_Tracked] = []
        order = sorted(self._tracked.values(),
                       key=lambda t: _PRIORITY_RANK[t.priority])
        for t in order:
            cands = [d for d in self._live(t.priority)
                     if len(assign[d.device_id]) < self.cfg.max_group_size]
            groups = [sorted(assign[d.device_id] + [t],
                             key=lambda x: x.pos) for d in cands]
            prices = self._price([(d.model, g)
                                  for d, g in zip(cands, groups)])
            best = None
            for di, (gain, meets, _, _) in enumerate(prices):
                if meets and (best is None or gain > best[0]):
                    best = (gain, di)
            if best is None:
                unplaced.append(t)
            else:
                d = cands[best[1]]
                assign[d.device_id].append(t)
                info[d.device_id] = prices[best[1]]
        return assign, info, unplaced

    def _price(self, items: List[Tuple[DeviceModel, List[_Tracked]]]
               ) -> List[_Price]:
        """Price candidate groups, deduplicated by ``(model, uids)``
        against the fleet cache and batched into one solve per phase.
        A group's price is its FINAL resolved value: full sharing when
        feasible, else the best k-way slot-fraction partition."""
        out: List[Optional[_Price]] = [None] * len(items)
        missing: Dict[Tuple[str, Tuple[int, ...]], List[int]] = {}
        for i, (model, g) in enumerate(items):
            key = (model.name, tuple(x.uid for x in g))
            hit = self._price_cache.get(key)
            if hit is not None:
                out[i] = hit
            else:
                missing.setdefault(key, []).append(i)
        if missing:
            by_model: Dict[str, List[Tuple[Tuple, List[_Tracked], DeviceModel]]] = {}
            for key, idxs in missing.items():
                model, g = items[idxs[0]]
                if len(g) == 1:
                    w = g[0].profile
                    price = (1.0, True, {w.name: 1.0}, {})
                    self._price_cache[key] = price
                    for i in idxs:
                        out[i] = price
                else:
                    by_model.setdefault(model.name, []).append(
                        (key, g, model))
            for entries in by_model.values():
                self._price_multi(entries)
            for key, idxs in missing.items():
                for i in idxs:
                    if out[i] is None:
                        out[i] = self._price_cache[key]
        return out  # type: ignore[return-value]

    def _price_multi(self, entries) -> None:
        """One batched full-share solve over every missing >=2-member
        group on one device model, then one batched fraction search over
        the SLO-failing ones (the green-context fallback)."""
        model = entries[0][2]
        reps: Dict[str, KernelProfile] = {}
        for _, g, _ in entries:
            for t in g:
                reps[t.profile.name] = self._rep(t, model)
        scenarios = []
        for _, g, _ in entries:
            scenarios.extend(group_victim_scenarios(
                [t.profile for t in g], reps))
        br = solve_scenarios(scenarios, model)
        self.stats["scenarios_solved"] += len(scenarios)
        self.stats["groups_priced"] += len(entries)
        row = 0
        failing = []
        for key, g, _ in entries:
            members = [t.profile for t in g]
            n_rows = sum(len(w.kernels) for w in members)
            slows = member_slowdowns(members, model,
                                     br.slowdowns[row:row + n_rows, 0])
            row += n_rows
            gain, meets = group_metrics(
                [w.total_time(model) for w in members],
                [slows[w.name] for w in members],
                [w.slo_slowdown for w in members])
            self._price_cache[key] = (gain, meets,
                                      {n: float(s) for n, s in slows.items()},
                                      {})
            if not meets and self.cfg.allow_partition:
                failing.append((key, members))
        if failing:
            found = search_group_fractions(
                [m for _, m in failing], model, self.search, reps=reps,
                stats=self.stats)
            for (key, members), res in zip(failing, found):
                if res.meets_slo:
                    names = [w.name for w in members]
                    self._price_cache[key] = (
                        float(res.gain), True,
                        {n: float(s) for n, s in res.slowdowns.items()},
                        dict(zip(names, map(float, res.fractions))))

    def _rep(self, t: _Tracked, model: DeviceModel) -> KernelProfile:
        key = (t.uid, model.name)
        rep = self._reps.get(key)
        if rep is None:
            rep = self._reps[key] = t.profile.representative_kernel(model)
        return rep

    def _drop_prices(self, uid: int) -> None:
        for key in [k for k in self._price_cache if uid in k[1]]:
            del self._price_cache[key]
        for key in [k for k in self._reps if k[0] == uid]:
            del self._reps[key]

    # ----------------------------- replanning ---------------------- #
    def _replan(self, reason: str,
                retry_due: frozenset = frozenset()) -> None:
        """Recompute the assignment, record every transition as a
        decision, update lifecycle states, and sync per-device
        schedulers.  Guarded: never raises (the no-crash contract)."""
        self.stats["replans"] += 1
        try:
            assign, info, unplaced = self._replay()
            self._apply_replay(assign, info, unplaced, reason, retry_due)
        except Exception as e:
            self._error(f"replan ({reason}): {e!r}")

    def _apply_replay(self, assign, info, unplaced, reason,
                      retry_due) -> None:
        now = self.clock()
        new_assignment = {t.profile.name: did
                          for did, members in assign.items()
                          for t in members}
        unplaced_names = {t.profile.name for t in unplaced}
        for name, t in self._tracked.items():
            old = self._assignment.get(name)
            new = new_assignment.get(name)
            if new is not None:
                if old is None:
                    self._decide("placed", t, device=new, reason=reason)
                elif old != new:
                    self.stats["migrated"] += 1
                    self._decide("migrated", t, device=new,
                                 reason=f"{reason}; was on {old}")
                t.state, t.device = PLACED, new
                t.retries, t.next_retry = 0, 0.0
            elif name in unplaced_names:
                if old is not None:
                    # displaced from a placement it held
                    action = ("evicted" if t.priority == BEST_EFFORT
                              else "displaced")
                    self.stats[action] += 1
                    t.state, t.device = QUEUED, None
                    t.retries = 0
                    t.next_retry = now + self.cfg.backoff_base
                    self._decide(action, t, device=old, reason=reason)
                elif t.state == QUEUED and name in retry_due:
                    t.retries += 1
                    self.stats["retries"] += 1
                    if t.retries >= self.cfg.max_retries:
                        t.state = DEGRADED
                        self._decide(
                            "degraded", t,
                            reason=f"no capacity after {t.retries} retries "
                                   f"({reason})")
                    else:
                        t.next_retry = (now + self.cfg.backoff_base
                                        * 2 ** t.retries)
                        self._decide(
                            "retry-failed", t,
                            reason=f"{reason}; backoff "
                                   f"{t.next_retry - now:.1f}s")
        self._assignment = new_assignment
        self._groups = assign
        self._info = info
        self._sync_devices(assign)

    def _sync_devices(self, assign: Dict[str, List[_Tracked]]) -> None:
        """Mirror the assignment into each device's ColocationScheduler
        (residency tracking only — pricing there stays lazy/unused)."""
        for did, members in assign.items():
            dev = self.devices[did]
            want = {t.profile.name: t for t in members}
            for name in [n for n in dev.resident_uids if n not in want]:
                dev.sched.remove(name)
                del dev.resident_uids[name]
            for name, t in want.items():
                if dev.resident_uids.get(name) != t.uid:
                    dev.sched.submit(t.profile)
                    dev.resident_uids[name] = t.uid

    def _mark_dead(self, dev: FleetDevice, reason: str) -> None:
        dev.state = D_DEAD
        self.heartbeats.forget(dev.device_id)
        drained = dev.sched.drain()          # the migration hook
        dev.resident_uids.clear()
        self.stats["device_deaths"] += 1
        self._decide("device-dead", device=dev.device_id,
                     reason=f"{reason}; drained {len(drained)} workloads")
        # plan_rescale wiring: displaced elastic-training workloads get
        # a concrete recovery plan (shrunk mesh, same global batch)
        for w in drained:
            t = self._tracked.get(w.name)
            if t is not None and t.train_meta:
                m = t.train_meta
                t.rescale = plan_rescale(
                    m["mesh_shape"], lost_chips=dev.chips,
                    global_batch=m.get("global_batch", 0),
                    num_microbatches=m.get("num_microbatches", 1),
                    current_step=m.get("step", 0))
                self._decide(
                    "rescale-planned", t,
                    reason=f"lost {dev.chips} chip(s) on {dev.device_id}: "
                           f"{m['mesh_shape']} -> {t.rescale.new_shape} "
                           f"({t.rescale.new_chip_count} chips), resume "
                           f"step {t.rescale.restart_step}")

    # ----------------------------- reporting ----------------------- #
    def plan(self) -> FleetPlan:
        """The current fleet state.  Pure read: placements come from the
        last replay (every mutation already replanned)."""
        placements = {}
        for did, members in self._groups.items():
            if not members:
                continue
            gain, _, slows, fracs = self._info[did]
            names = [t.profile.name for t in
                     sorted(members, key=lambda x: x.pos)]
            placements[did] = Placement(
                names, dict(fracs),
                {n: float(slows[n]) for n in names}, True, float(gain))
        return FleetPlan(
            placements=placements,
            queued=[n for n, t in self._tracked.items()
                    if t.state == QUEUED],
            degraded=[n for n, t in self._tracked.items()
                      if t.state == DEGRADED],
            device_states={did: d.state for did, d in self.devices.items()})

    def snapshot(self) -> Dict:
        """Full fleet telemetry: device snapshots (via the per-device
        scheduler hook), workload lifecycle states, queue depths, stats."""
        return {
            "devices": {did: {"state": d.state, "model": d.model.name,
                              "chips": d.chips,
                              "sched": d.sched.snapshot()}
                        for did, d in self.devices.items()},
            "workloads": {n: self.workload_state(n) for n in self._tracked},
            "queued": sum(t.state == QUEUED
                          for t in self._tracked.values()),
            "degraded_workloads": sum(t.state == DEGRADED
                                      for t in self._tracked.values()),
            "decisions": len(self.decisions),
            "stats": dict(self.stats),
        }

    # ----------------------------- internals ----------------------- #
    def _decide(self, action: str, t: Optional[_Tracked] = None,
                device: Optional[str] = None, reason: str = ""
                ) -> AdmissionDecision:
        d = AdmissionDecision(
            seq=self._seq, time=self.clock(), action=action,
            workload=t.profile.name if t is not None else None,
            priority=t.priority if t is not None else None,
            device=device, reason=reason)
        self._seq += 1
        self.decisions.append(d)
        return d

    def _error(self, reason: str) -> None:
        self.stats["errors"] += 1
        self._decide("error", reason=reason)
