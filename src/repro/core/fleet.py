"""Fault-tolerant fleet scheduling over the colocation core.

The ``ColocationScheduler`` plans ONE device; production means a fleet:
admission control, priority classes, preemption, and — the part a happy
path never exercises — surviving device failure.  ``FleetScheduler``
owns a set of ``DeviceModel``-backed devices, each wrapping its own
``ColocationScheduler`` (the residency tracker with drain/snapshot
hooks), and keeps the whole system live through faults:

  * **Admission control** — arrivals are SLO or best-effort; every
    outcome (placed / queued / rejected / evicted / migrated / degraded)
    is an explicit ``AdmissionDecision`` in ``decisions``.  Unplaced
    workloads wait in bounded per-class queues; beyond
    ``FleetConfig.queue_limit`` an arrival is REJECTED with a record,
    never silently grown.
  * **Preemption** — placement replays SLO workloads before best-effort,
    so an SLO arrival that cannot otherwise fit displaces best-effort
    work (each eviction recorded); evicted workloads stay tracked and
    re-place the moment capacity returns.
  * **Failure handling** — the ``repro.ft`` primitives are wired into
    the event loop: a device that misses its heartbeat
    (``HeartbeatTracker`` on an injectable monotonic clock) is declared
    dead, its ``ColocationScheduler`` drains, and its workloads re-place
    on the survivors; a straggling device (``StragglerMonitor`` EWMA)
    degrades — SLO work migrates off, best-effort may stay; training
    workloads that lose chips get a ``plan_rescale`` elastic-rescale
    plan attached to their record.  Placement retries back off
    exponentially; a workload the surviving fleet genuinely cannot hold
    lands in a final "degraded" state — tracked, reported, retried when
    capacity changes, never dropped and never a crash (``tick`` seals
    the event loop: internal failures become ``action="error"``
    decisions, not exceptions).

**Determinism / the repair contract.**  Every mutation computes a
``RepairScope`` (the workloads needing placement plus the devices it
touched) and hands it to the ``RepairPlanner`` (`repro.core.repair`):
small/wide scopes take the historical deterministic full replay —
priority classes in order, arrival order within a class, each workload
on the max-gain feasible device (earliest device on ties) — while local
scopes at scale take a **scoped repair** that replays only the scope,
with an explicit bounded-divergence contract (total gain ≥ (1 − ε) ×
the cold replay, identical SLO placement set; see ``repro.core.repair``
for the fallback rules).  Pricing is batched per replay step and
DEDUPLICATED across devices and events by a fleet-level price cache
keyed ``(device model, member uids)``.  On fleets small enough that
every scope is fleet-wide (the historical gate sizes) the full-replay
path always runs, so the online fleet state after any fault trace still
equals a cold ``FleetScheduler`` plan over the surviving devices and
workloads — ``benchmarks/bench_fleet.py`` enforces that at 1e-9, and
gates the divergence contract at scale.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Set, Tuple)

from repro.core.backend import warmup_solver
from repro.core.estimator import solve_scenarios
from repro.core.fracsearch import (FractionSearchConfig, group_metrics,
                                   member_slowdowns, search_group_fractions)
from repro.core.profile import KernelProfile, WorkloadProfile
# lifecycle constants live in repro.core.repair (shared with the
# planner); re-exported here for the historical import path
from repro.core.repair import (BEST_EFFORT, D_DEAD, D_DEGRADED, D_HEALTHY,
                               DEGRADED, PLACED, QUEUED, SLO, _PRIORITY_RANK,
                               RepairPlanner, RepairRecord, RepairResult,
                               RepairScope)
from repro.core.resources import DeviceModel
from repro.core.scenario import group_victim_scenarios
from repro.core.scheduler import ColocationScheduler, Placement
from repro.ft import (HeartbeatTracker, RescalePlan, StragglerMonitor,
                      plan_rescale)


@dataclass(frozen=True)
class FleetConfig:
    """Fleet-level knobs (per-device colocation inherits the core's).

    max_group_size: colocation capacity of one device (workloads that
        must share it feasibly — the per-device ``ColocationScheduler``
        limit).
    queue_limit: bounded admission queue PER priority class; arrivals
        beyond it are rejected with a decision record.
    heartbeat_timeout: virtual seconds without a beat before a device is
        declared dead and drained.
    max_retries / backoff_base: placement retries for queued workloads
        back off as ``backoff_base * 2**retries``; after ``max_retries``
        failed due-retries the workload enters the final "degraded"
        state (still tracked, re-attempted on capacity changes).
    allow_partition / fraction_search: forwarded to group pricing — an
        SLO-violating candidate group falls back to the k-way
        slot-fraction search exactly like the single-device scheduler.
    straggler_factor / straggler_warmup: per-device ``StragglerMonitor``
        EWMA detection knobs.
    repair_mode: "scoped" (default) routes local mutations through the
        scoped repair path at scale; "full" forces the historical cold
        replay on every mutation (the 1e-9 online==cold behavior,
        unconditionally).
    repair_probe: how many of the emptiest live devices a scoped repair
        considers as placement candidates beyond the scope's own.
    full_replay_fraction: a scope touching more than this fraction of
        the live fleet falls back to the full replay (which also makes
        every fleet of ≲ repair_probe / fraction devices take the
        full-replay path always).
    divergence_epsilon: the bounded-divergence contract's ε — scoped
        total gain must stay ≥ (1 − ε) × the cold replay's (asserted by
        tests and the bench_fleet scale gate; advisory at runtime).
    warmup_solver: ahead-of-time compile the jax solver's common
        (bucket, K) shapes at construction (no-op on the numpy
        backend) so the first replans don't pay per-shape XLA compiles.
    """
    max_group_size: int = 3
    queue_limit: int = 16
    heartbeat_timeout: float = 5.0
    max_retries: int = 3
    backoff_base: float = 1.0
    allow_partition: bool = True
    fraction_search: Optional[FractionSearchConfig] = None
    straggler_factor: float = 3.0
    straggler_warmup: int = 3
    repair_mode: str = "scoped"
    repair_probe: int = 8
    full_replay_fraction: float = 0.25
    divergence_epsilon: float = 0.05
    warmup_solver: bool = False


@dataclass(frozen=True)
class AdmissionDecision:
    """One audit-log entry: what the fleet decided, when, and why."""
    seq: int
    time: float
    action: str                 # placed|queued|rejected|evicted|displaced|
                                # migrated|retry-failed|degraded|removed|
                                # device-dead|device-degraded|
                                # device-recovered|rescale-planned|error
    workload: Optional[str] = None
    priority: Optional[str] = None
    device: Optional[str] = None
    reason: str = ""

    def __repr__(self):
        who = self.workload or self.device or "-"
        return (f"<#{self.seq} t={self.time:.2f} {self.action} {who}"
                f"{' @' + self.device if self.workload and self.device else ''}"
                f" ({self.reason})>")


@dataclass
class _Tracked:
    """Internal per-workload record (arrival order = dict order).

    ``uid`` bumps on every (re)submit — it versions the price cache;
    ``pos`` is the stable arrival position — it orders replay and
    canonical group membership, so a resubmitted workload keeps its
    place (and an online trace keeps matching the cold replay)."""
    profile: WorkloadProfile
    priority: str
    uid: int
    pos: int = 0
    state: str = QUEUED
    device: Optional[str] = None
    retries: int = 0
    next_retry: float = 0.0
    train_meta: Optional[dict] = None    # mesh_shape/global_batch/... for
    rescale: Optional[RescalePlan] = None  # plan_rescale on chip loss


@dataclass
class FleetDevice:
    """One device: a DeviceModel wrapping its own ColocationScheduler."""
    device_id: str
    model: DeviceModel
    sched: ColocationScheduler
    monitor: StragglerMonitor
    chips: int = 1
    state: str = D_HEALTHY
    resident_uids: Dict[str, int] = field(default_factory=dict)


@dataclass
class FleetPlan:
    """The fleet's current placement state (see ``FleetScheduler.plan``)."""
    placements: Dict[str, Placement]     # device_id -> its colocation group
    queued: List[str]                    # admitted, waiting for capacity
    degraded: List[str]                  # final state: capacity insufficient
    device_states: Dict[str, str]

    @property
    def placed(self) -> Dict[str, str]:
        """workload name -> device_id."""
        return {n: did for did, p in self.placements.items()
                for n in p.workloads}

    @property
    def total_gain(self) -> float:
        """Sum of packed throughput gains over occupied devices — the
        quantity the bounded-divergence contract compares between a
        scoped-repaired fleet and a cold replay."""
        return sum(p.throughput_gain for p in self.placements.values())

    def placement_rate(self, names: Iterable[str]) -> float:
        """Fraction of ``names`` currently placed (1.0 for an empty set)."""
        names = list(names)
        if not names:
            return 1.0
        placed = self.placed
        return sum(n in placed for n in names) / len(names)


# fleet price record: (gain, meets_slo, slowdowns by name, fractions by name)
_Price = Tuple[float, bool, Dict[str, float], Dict[str, float]]


class FleetScheduler:
    """Admission control + placement + fault recovery over many devices.

    >>> clock = FakeClock()                      # repro.ft.inject
    >>> fleet = FleetScheduler({"dev0": TPU_V5E, "dev1": TPU_V5E},
    ...                        clock=clock)
    >>> fleet.submit(decode, priority=SLO)       # -> AdmissionDecision
    >>> fleet.heartbeat("dev0"); fleet.tick()    # the event loop
    >>> fleet.plan()                             # -> FleetPlan

    ``submit``/``remove`` raise on caller errors (unknown names, bad
    priority) exactly like ``ColocationScheduler``; the event-loop
    surface (``tick``, ``observe_step`` internals, replanning) never
    raises — failures become ``action="error"`` decisions.
    """

    def __init__(self, devices: Mapping[str, DeviceModel] | Iterable[Tuple[str, DeviceModel]],
                 config: Optional[FleetConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = config or FleetConfig()
        self.clock = clock
        self.search = (self.cfg.fraction_search
                       or FractionSearchConfig.default())
        self.devices: Dict[str, FleetDevice] = {}
        self.heartbeats = HeartbeatTracker(
            timeout_s=self.cfg.heartbeat_timeout, clock=clock)
        self._tracked: Dict[str, _Tracked] = {}      # arrival order
        self._next_uid = 0
        self._next_pos = 0
        self._seq = 0
        self.decisions: List[AdmissionDecision] = []
        self._price_cache: Dict[Tuple[str, Tuple[int, ...]], _Price] = {}
        self._reps: Dict[Tuple[int, str], KernelProfile] = {}
        # uid -> cache keys reverse indexes: departures drop exactly the
        # entries that mention the uid instead of scanning every key
        self._uid_price_keys: Dict[int, Set[Tuple[str, Tuple[int, ...]]]] = {}
        self._uid_rep_keys: Dict[int, Set[Tuple[int, str]]] = {}
        self._assignment: Dict[str, str] = {}        # name -> device_id
        self._groups: Dict[str, List[_Tracked]] = {}  # device_id -> members
        self._info: Dict[str, _Price] = {}           # device_id -> group price
        self.planner = RepairPlanner(self)
        self.repairs: List[RepairRecord] = []
        self.stats: Dict[str, int] = {
            "arrivals": 0, "departures": 0, "rejected": 0, "evicted": 0,
            "migrated": 0, "displaced": 0, "retries": 0, "device_deaths": 0,
            "replans": 0, "scoped_repairs": 0, "full_replays": 0,
            "repair_fallbacks": 0, "scenarios_solved": 0, "groups_priced": 0,
            "errors": 0,
            "calib_observations": 0, "calib_flags": 0, "calib_refits": 0,
        }
        self.calib = None                  # DriftMonitor, see repro.calib
        items = devices.items() if isinstance(devices, Mapping) else devices
        for did, model in items:
            self.add_device(did, model)
        if self.cfg.warmup_solver:
            # the jitted solver traces per (bucket, K) shape, shared
            # across device models — one warmup covers the whole fleet
            models = {d.model.name: d.model for d in self.devices.values()}
            for model in models.values():
                warmup_solver(model,
                              ks=range(2, self.cfg.max_group_size + 1))

    # ----------------------------- devices ------------------------ #
    def add_device(self, device_id: str, model: DeviceModel,
                   chips: int = 1) -> None:
        """Register a device; its heartbeat clock starts NOW (a device
        that never beats is declared dead after the timeout)."""
        if device_id in self.devices:
            raise ValueError(f"duplicate device: {device_id!r}")
        self.devices[device_id] = FleetDevice(
            device_id, model,
            ColocationScheduler(model,
                                max_group_size=self.cfg.max_group_size,
                                allow_partition=self.cfg.allow_partition,
                                fraction_search=self.search),
            StragglerMonitor(factor=self.cfg.straggler_factor,
                             warmup=self.cfg.straggler_warmup,
                             clock=self.clock),
            chips=chips)
        self.heartbeats.beat(device_id)
        if self._tracked:
            # new capacity: queued/degraded workloads get another shot
            self._replan(RepairScope(
                "capacity", f"device {device_id} added",
                workloads=self._waiting(), devices=(device_id,)))

    def heartbeat(self, device_id: str, now: Optional[float] = None) -> None:
        """A device host reports in.  A beat from a dead device revives
        it (the host came back): healthy again, capacity replanned."""
        dev = self.devices.get(device_id)
        if dev is None:
            raise KeyError(f"unknown device: {device_id!r}")
        self.heartbeats.beat(device_id, now)
        if dev.state == D_DEAD:
            dev.state = D_HEALTHY
            self._decide("device-recovered", device=device_id,
                         reason="heartbeat resumed")
            self._replan(RepairScope(
                "capacity", f"device {device_id} recovered",
                workloads=self._waiting(), devices=(device_id,)))

    def revive_device(self, device_id: str) -> None:
        """Operator override: clear a device's degraded (straggler) state."""
        dev = self.devices[device_id]
        if dev.state == D_DEGRADED:
            dev.state = D_HEALTHY
            dev.monitor.ewma = None
            dev.monitor.n = 0
            self._decide("device-recovered", device=device_id,
                         reason="straggle cleared")
            self._replan(RepairScope(
                "capacity", f"device {device_id} revived",
                workloads=self._waiting(), devices=(device_id,)))

    def decommission(self, device_id: str) -> None:
        """Planned removal: drain the device and re-place its workloads
        (same migration path as a failure, minus the timeout wait)."""
        dev = self.devices.get(device_id)
        if dev is None:
            raise KeyError(f"unknown device: {device_id!r}")
        if dev.state == D_DEAD:
            return                      # documented no-op: already drained
        residents = self._residents(device_id)
        self._mark_dead(dev, reason="decommissioned")
        self._replan(RepairScope(
            "device-dead", f"device {device_id} decommissioned",
            workloads=residents))

    def observe_step(self, device_id: str, step: int, dt: float) -> bool:
        """Feed one step-time observation to the device's straggler
        monitor; EWMA detection degrades the device (SLO work migrates
        off at the next replan, best-effort may remain)."""
        dev = self.devices.get(device_id)
        if dev is None:
            raise KeyError(f"unknown device: {device_id!r}")
        try:
            straggling = dev.monitor.observe(step, dt)
            if straggling and dev.state == D_HEALTHY:
                dev.state = D_DEGRADED
                self._decide("device-degraded", device=device_id,
                             reason=f"straggling: dt={dt:.3g} vs "
                                    f"ewma={dev.monitor.ewma:.3g}")
                # SLO residents must migrate off; best-effort may stay
                slo_res = tuple(n for n in self._residents(device_id)
                                if self._tracked[n].priority == SLO)
                self._replan(RepairScope(
                    "device-degraded", f"device {device_id} degraded",
                    workloads=slo_res, devices=(device_id,)))
            return straggling
        except Exception as e:      # pragma: no cover - defensive seal
            self._error(f"observe_step({device_id}): {e!r}")
            return False

    # ----------------------------- workloads ----------------------- #
    def __len__(self) -> int:
        return len(self._tracked)

    def __contains__(self, name: str) -> bool:
        return name in self._tracked

    @property
    def workloads(self) -> List[Tuple[WorkloadProfile, str]]:
        """(profile, priority) pairs in arrival order — exactly what a
        cold fleet over the survivors must be fed to reproduce the
        online plan (the recovery gate's contract)."""
        return [(t.profile, t.priority) for t in self._tracked.values()]

    def workload_state(self, name: str) -> Dict:
        t = self._tracked[name]
        return {"state": t.state, "device": t.device, "priority": t.priority,
                "retries": t.retries, "next_retry": t.next_retry,
                "rescale": t.rescale}

    def profile_of(self, name: str) -> WorkloadProfile:
        """The fleet's current believed profile for a tracked workload."""
        return self._tracked[name].profile

    # ----------------------------- calibration --------------------- #
    def attach_calibration(self, monitor) -> None:
        """Wire a ``repro.calib.DriftMonitor`` into the event loop:
        ``observe_slowdown`` feeds it predicted-vs-observed pairs and
        ``refit_workload`` re-fits flagged tenants from its samples.
        Counters surface in ``stats`` (calib_observations/flags/refits)."""
        self.calib = monitor

    def observe_slowdown(self, name: str, observed: float) -> bool:
        """Report a measured slowdown for a placed workload.  Builds the
        drift sample's colocation context (group-mate representative
        kernels, slot fractions, device model) from the live plan and
        forwards to the attached monitor.  Returns True iff this
        observation NEWLY flags the workload as drifted.  Event-loop
        surface: never raises."""
        try:
            if self.calib is None:
                return False
            t = self._tracked.get(name)
            if t is None or t.state != PLACED or t.device is None:
                return False
            info = self._info.get(t.device)
            if info is None:
                return False
            predicted = info[2].get(name)
            if predicted is None:
                return False
            model = self.devices[t.device].model
            bg = tuple(self._rep(o, model)
                       for o in self._groups.get(t.device, ())
                       if o.profile.name != name)
            frac = info[3] or None
            self.stats["calib_observations"] += 1
            newly = self.calib.observe(name, predicted, float(observed),
                                       bg, frac, model)
            if newly:
                self.stats["calib_flags"] += 1
                self._decide("calib-flagged", t, device=t.device,
                             reason=f"observed/predicted diverged "
                                    f"{self.calib.divergence(name):+.0%} "
                                    f"(EWMA)")
            return newly
        except Exception as e:
            self._error(f"observe_slowdown {name}: {e!r}")
            return False

    def refit_workload(self, name: str) -> bool:
        """Re-fit a drifted workload's profile from the monitor's stored
        observations and resubmit it (same priority — last-profile-wins,
        so the fleet replans around the corrected demand).  Returns True
        iff a refit happened.  Never raises."""
        try:
            if self.calib is None:
                return False
            t = self._tracked.get(name)
            if t is None or not self.calib.can_refit(name):
                return False
            refit = self.calib.refit(name, t.profile)
            if refit is None:
                return False
            self.stats["calib_refits"] += 1
            self._decide("calib-refit", t, device=t.device,
                         reason="profile re-fit from drift observations")
            self.submit(refit, priority=t.priority,
                        train_meta=t.train_meta)
            return True
        except Exception as e:
            self._error(f"refit_workload {name}: {e!r}")
            return False

    def submit(self, workload: WorkloadProfile, priority: str = SLO,
               train_meta: Optional[dict] = None) -> AdmissionDecision:
        """Admit a workload and decide its fate NOW: returns the
        decision record (placed / queued / rejected).  Re-submitting an
        existing name replaces its profile and priority but keeps its
        arrival position (the core scheduler's last-profile-wins rule);
        its cached prices are invalidated.

        ``train_meta`` (optional) marks an elastic training job:
        ``{"mesh_shape": {...}, "global_batch": int,
        "num_microbatches": int, "step": int}`` — if its device later
        dies, a ``plan_rescale`` recovery plan is attached to the
        workload record and surfaced as a "rescale-planned" decision.
        """
        if priority not in _PRIORITY_RANK:
            raise ValueError(f"priority must be {SLO!r} or {BEST_EFFORT!r},"
                             f" got {priority!r}")
        name = workload.name
        old = self._tracked.get(name)
        old_dev: Tuple[str, ...] = ()
        if old is not None:
            if old.device is not None:
                old_dev = (old.device,)
            self._drop_prices(old.uid)
            old.profile = workload
            old.priority = priority
            old.uid = self._next_uid
            old.train_meta = train_meta if train_meta else old.train_meta
            t = old
        else:
            t = self._tracked[name] = _Tracked(workload, priority,
                                               self._next_uid,
                                               pos=self._next_pos,
                                               train_meta=train_meta)
            self._next_pos += 1
        self._next_uid += 1
        self.stats["arrivals"] += 1
        n0 = len(self.decisions)
        self._replan(RepairScope("arrival", f"arrival {name}",
                                 workloads=(name,), devices=old_dev))
        if t.state == PLACED:
            for d in self.decisions[n0:]:
                if d.workload == name and d.action in ("placed", "migrated"):
                    return d
            return self._decide("placed", t, device=t.device,
                                reason=f"arrival {name} (placement unchanged)")
        # not placeable now: bounded queue or explicit rejection
        backlog = sum(1 for o in self._tracked.values()
                      if o.state in (QUEUED, DEGRADED)
                      and o.priority == priority)
        if backlog > self.cfg.queue_limit:
            del self._tracked[name]
            self._drop_prices(t.uid)
            self.stats["rejected"] += 1
            return self._decide(
                "rejected", t,
                reason=f"{priority} queue full "
                       f"({self.cfg.queue_limit} waiting)")
        t.next_retry = self.clock() + self.cfg.backoff_base
        return self._decide("queued", t,
                            reason=f"no feasible device; retry in "
                                   f"{self.cfg.backoff_base:.1f}s")

    def submit_many(self, arrivals: Sequence) -> List[AdmissionDecision]:
        """Admit a same-tick arrival storm in ONE deduplicated replay.

        ``arrivals`` holds ``(workload, priority)`` or ``(workload,
        priority, train_meta)`` tuples.  Semantically equivalent to
        calling ``submit`` per item — queued workloads never occupy a
        device, so registering every arrival first and replanning once
        yields the same final placements and the same bounded-queue
        admission outcomes — but it costs one replay (and one round of
        group pricing) instead of one per arrival.  Duplicate names in
        the batch collapse to the last profile (last-profile-wins, as
        with re-submission).  Returns one decision per distinct name in
        first-submission order.
        """
        items = []
        for entry in arrivals:
            workload, priority = entry[0], entry[1]
            train_meta = entry[2] if len(entry) > 2 else None
            if priority not in _PRIORITY_RANK:
                raise ValueError(f"priority must be {SLO!r} or "
                                 f"{BEST_EFFORT!r}, got {priority!r}")
            items.append((workload, priority, train_meta))
        if not items:
            return []
        order: List[str] = []
        old_devs: List[str] = []
        for workload, priority, train_meta in items:
            name = workload.name
            old = self._tracked.get(name)
            if old is not None:
                if old.device is not None and old.device not in old_devs:
                    old_devs.append(old.device)
                self._drop_prices(old.uid)
                old.profile = workload
                old.priority = priority
                old.uid = self._next_uid
                old.train_meta = train_meta if train_meta else old.train_meta
            else:
                self._tracked[name] = _Tracked(workload, priority,
                                               self._next_uid,
                                               pos=self._next_pos,
                                               train_meta=train_meta)
                self._next_pos += 1
            self._next_uid += 1
            self.stats["arrivals"] += 1
            if name not in order:
                order.append(name)
        n0 = len(self.decisions)
        self._replan(RepairScope(
            "storm", f"arrival storm ({len(order)} workloads)",
            workloads=tuple(order), devices=tuple(old_devs)))
        batch = set(order)
        placed_dec: Dict[str, AdmissionDecision] = {}
        for d in self.decisions[n0:]:
            if d.workload in batch and d.action in ("placed", "migrated"):
                placed_dec.setdefault(d.workload, d)
        out: List[AdmissionDecision] = []
        for name in order:
            t = self._tracked[name]
            if t.state == PLACED:
                d = placed_dec.get(name)
                out.append(d if d is not None else self._decide(
                    "placed", t, device=t.device,
                    reason=f"arrival {name} (placement unchanged)"))
                continue
            backlog = sum(1 for o in self._tracked.values()
                          if o.state in (QUEUED, DEGRADED)
                          and o.priority == t.priority)
            if backlog > self.cfg.queue_limit:
                del self._tracked[name]
                self._drop_prices(t.uid)
                self.stats["rejected"] += 1
                out.append(self._decide(
                    "rejected", t,
                    reason=f"{t.priority} queue full "
                           f"({self.cfg.queue_limit} waiting)"))
                continue
            t.next_retry = self.clock() + self.cfg.backoff_base
            out.append(self._decide(
                "queued", t,
                reason=f"no feasible device; retry in "
                       f"{self.cfg.backoff_base:.1f}s"))
        return out

    def remove(self, name: str) -> None:
        """A workload departs.  Unknown names raise ``KeyError`` before
        any state is touched (mirrors ``ColocationScheduler.remove``)."""
        t = self._tracked.get(name)
        if t is None:
            raise KeyError(f"unknown workload: {name!r}")
        del self._tracked[name]
        self._drop_prices(t.uid)
        self._assignment.pop(name, None)
        if self.calib is not None:
            self.calib.forget(name)
        self.stats["departures"] += 1
        self._decide("removed", t, device=t.device, reason="departure")
        # freed capacity: waiting workloads get another shot; the
        # departed workload's device re-prices its shrunken group
        self._replan(RepairScope(
            "departure", f"departure {name}", workloads=self._waiting(),
            devices=(t.device,) if t.device is not None else ()))

    # ----------------------------- event loop ---------------------- #
    def tick(self, now: Optional[float] = None) -> None:
        """One controller iteration: scan heartbeats (missed ->
        dead + drain), fire due placement retries.  NEVER raises —
        internal failures become ``action="error"`` decisions."""
        try:
            now = self.clock() if now is None else now
            dead = [w for w in self.heartbeats.dead_workers(now)
                    if w in self.devices
                    and self.devices[w].state != D_DEAD]
            displaced: List[str] = []
            for did in dead:
                displaced.extend(self._residents(did))
                self._mark_dead(self.devices[did],
                                reason=f"missed heartbeat for "
                                       f">{self.cfg.heartbeat_timeout:.1f}s")
            retry_due = frozenset(
                n for n, t in self._tracked.items()
                if t.state == QUEUED and t.next_retry <= now)
            scope = None
            if dead:
                scope = RepairScope("device-dead",
                                    "device failure: " + ", ".join(dead),
                                    workloads=tuple(displaced))
            if retry_due:
                retry = RepairScope("retry",
                                    "retry " + ", ".join(sorted(retry_due)),
                                    workloads=tuple(sorted(retry_due)))
                scope = retry if scope is None else scope.merge(retry)
            if scope is not None:
                self._replan(scope, retry_due=retry_due)
        except Exception as e:
            self._error(f"tick: {e!r}")

    @property
    def degraded(self) -> bool:
        """True when the fleet is running in degraded mode: a device is
        dead/straggling or a workload cannot be placed on the survivors."""
        return (any(d.state != D_HEALTHY for d in self.devices.values())
                or any(t.state == DEGRADED for t in self._tracked.values()))

    # ----------------------------- placement ----------------------- #
    def _live(self, priority: str) -> List[FleetDevice]:
        """Devices this priority class may use, in registry order: SLO
        only healthy; best-effort also degraded (slow) devices."""
        ok = (D_HEALTHY,) if priority == SLO else (D_HEALTHY, D_DEGRADED)
        return [d for d in self.devices.values() if d.state in ok]

    def _waiting(self) -> Tuple[str, ...]:
        """Names waiting for capacity (queued or final-degraded) — the
        workload scope of every capacity-increasing mutation."""
        return tuple(n for n, t in self._tracked.items()
                     if t.state in (QUEUED, DEGRADED))

    def _residents(self, device_id: str) -> Tuple[str, ...]:
        """Names currently assigned to a device (by the last replan)."""
        return tuple(t.profile.name
                     for t in self._groups.get(device_id, ())
                     if t.profile.name in self._tracked)

    def _price(self, items: List[Tuple[DeviceModel, List[_Tracked]]]
               ) -> List[_Price]:
        """Price candidate groups, deduplicated by ``(model, uids)``
        against the fleet cache and batched into one solve per phase.
        A group's price is its FINAL resolved value: full sharing when
        feasible, else the best k-way slot-fraction partition."""
        out: List[Optional[_Price]] = [None] * len(items)
        missing: Dict[Tuple[str, Tuple[int, ...]], List[int]] = {}
        for i, (model, g) in enumerate(items):
            key = (model.name, tuple(x.uid for x in g))
            hit = self._price_cache.get(key)
            if hit is not None:
                out[i] = hit
            else:
                missing.setdefault(key, []).append(i)
        if missing:
            by_model: Dict[str, List[Tuple[Tuple, List[_Tracked], DeviceModel]]] = {}
            for key, idxs in missing.items():
                model, g = items[idxs[0]]
                if len(g) == 1:
                    w = g[0].profile
                    price = (1.0, True, {w.name: 1.0}, {})
                    self._cache_price(key, price)
                    for i in idxs:
                        out[i] = price
                else:
                    by_model.setdefault(model.name, []).append(
                        (key, g, model))
            for entries in by_model.values():
                self._price_multi(entries)
            for key, idxs in missing.items():
                for i in idxs:
                    if out[i] is None:
                        out[i] = self._price_cache[key]
        return out  # type: ignore[return-value]

    def _price_multi(self, entries) -> None:
        """One batched full-share solve over every missing >=2-member
        group on one device model, then one batched fraction search over
        the SLO-failing ones (the green-context fallback)."""
        model = entries[0][2]
        reps: Dict[str, KernelProfile] = {}
        for _, g, _ in entries:
            for t in g:
                reps[t.profile.name] = self._rep(t, model)
        scenarios = []
        for _, g, _ in entries:
            scenarios.extend(group_victim_scenarios(
                [t.profile for t in g], reps))
        br = solve_scenarios(scenarios, model)
        self.stats["scenarios_solved"] += len(scenarios)
        self.stats["groups_priced"] += len(entries)
        row = 0
        failing = []
        for key, g, _ in entries:
            members = [t.profile for t in g]
            n_rows = sum(len(w.kernels) for w in members)
            slows = member_slowdowns(members, model,
                                     br.slowdowns[row:row + n_rows, 0])
            row += n_rows
            gain, meets = group_metrics(
                [w.total_time(model) for w in members],
                [slows[w.name] for w in members],
                [w.slo_slowdown for w in members])
            self._cache_price(key, (gain, meets,
                                    {n: float(s) for n, s in slows.items()},
                                    {}))
            if not meets and self.cfg.allow_partition:
                failing.append((key, members))
        if failing:
            found = search_group_fractions(
                [m for _, m in failing], model, self.search, reps=reps,
                stats=self.stats)
            for (key, members), res in zip(failing, found):
                if res.meets_slo:
                    names = [w.name for w in members]
                    self._cache_price(key, (
                        float(res.gain), True,
                        {n: float(s) for n, s in res.slowdowns.items()},
                        dict(zip(names, map(float, res.fractions)))))

    def _rep(self, t: _Tracked, model: DeviceModel) -> KernelProfile:
        key = (t.uid, model.name)
        rep = self._reps.get(key)
        if rep is None:
            rep = self._reps[key] = t.profile.representative_kernel(model)
            self._uid_rep_keys.setdefault(t.uid, set()).add(key)
        return rep

    def _cache_price(self, key: Tuple[str, Tuple[int, ...]],
                     price: _Price) -> None:
        """Insert into the price cache, maintaining the uid -> keys
        reverse index that makes departures O(keys touched)."""
        self._price_cache[key] = price
        for uid in key[1]:
            self._uid_price_keys.setdefault(uid, set()).add(key)

    def _drop_prices(self, uid: int) -> None:
        # .pop(key, None): a key may already be gone when a group-mate's
        # earlier departure dropped the shared entry
        for key in self._uid_price_keys.pop(uid, ()):
            self._price_cache.pop(key, None)
        for key in self._uid_rep_keys.pop(uid, ()):
            self._reps.pop(key, None)

    # ----------------------------- replanning ---------------------- #
    def _replan(self, scope: RepairScope,
                retry_due: frozenset = frozenset()) -> None:
        """Route one mutation's scope through the RepairPlanner, apply
        the result, and record the repair.  Guarded: never raises (the
        no-crash contract)."""
        self.stats["replans"] += 1
        t0 = time.perf_counter()
        try:
            res = self.planner.plan(scope, retry_due)
            self._apply(res, scope.reason, retry_due)
            self.repairs.append(RepairRecord(
                kind=scope.kind, reason=scope.reason, full=res.full,
                targets=len(res.targets),
                devices_touched=len(res.touched),
                latency_s=time.perf_counter() - t0))
        except Exception as e:
            self._error(f"replan ({scope.reason}): {e!r}")

    def _apply(self, res: RepairResult, reason: str, retry_due) -> None:
        """The thin apply layer: record every lifecycle transition the
        computed assignment implies, then merge it into fleet state —
        wholesale for a full replay, as a delta for a scoped repair."""
        now = self.clock()
        if res.full:
            scan = list(self._tracked.items())
        else:
            scan = [(n, self._tracked[n]) for n in res.targets
                    if n in self._tracked]
        unplaced_names = {t.profile.name for t in res.unplaced}
        for name, t in scan:
            old = self._assignment.get(name)
            new = res.placement.get(name)
            if new is not None:
                if old is None:
                    self._decide("placed", t, device=new, reason=reason)
                elif old != new:
                    self.stats["migrated"] += 1
                    self._decide("migrated", t, device=new,
                                 reason=f"{reason}; was on {old}")
                t.state, t.device = PLACED, new
                t.retries, t.next_retry = 0, 0.0
                if not res.full:
                    self._assignment[name] = new
            elif name in unplaced_names:
                if old is not None:
                    # displaced from a placement it held
                    action = ("evicted" if t.priority == BEST_EFFORT
                              else "displaced")
                    self.stats[action] += 1
                    t.state, t.device = QUEUED, None
                    t.retries = 0
                    t.next_retry = now + self.cfg.backoff_base
                    self._decide(action, t, device=old, reason=reason)
                    if not res.full:
                        self._assignment.pop(name, None)
                elif t.state == QUEUED and name in retry_due:
                    t.retries += 1
                    self.stats["retries"] += 1
                    if t.retries >= self.cfg.max_retries:
                        t.state = DEGRADED
                        self._decide(
                            "degraded", t,
                            reason=f"no capacity after {t.retries} retries "
                                   f"({reason})")
                    else:
                        t.next_retry = (now + self.cfg.backoff_base
                                        * 2 ** t.retries)
                        self._decide(
                            "retry-failed", t,
                            reason=f"{reason}; backoff "
                                   f"{t.next_retry - now:.1f}s")
        if res.full:
            self._assignment = dict(res.placement)
            self._groups = res.assign
            self._info = {did: p for did, p in res.info.items()
                          if p is not None}
            self._sync_devices(res.assign)
        else:
            for did, members in res.assign.items():
                self._groups[did] = members
                p = res.info.get(did)
                if members and p is not None:
                    self._info[did] = p
                else:
                    self._info.pop(did, None)
            # a scoped apply never rebuilds _groups wholesale, so dead
            # devices' stale entries must be pruned explicitly
            for did in [d for d in self._groups
                        if self.devices[d].state == D_DEAD]:
                self._groups.pop(did, None)
                self._info.pop(did, None)
            self._sync_devices(res.assign)

    def _sync_devices(self, assign: Dict[str, List[_Tracked]]) -> None:
        """Mirror the assignment into each device's ColocationScheduler
        (residency tracking only — pricing there stays lazy/unused)."""
        for did, members in assign.items():
            dev = self.devices[did]
            want = {t.profile.name: t for t in members}
            for name in [n for n in dev.resident_uids if n not in want]:
                dev.sched.remove(name)
                del dev.resident_uids[name]
            for name, t in want.items():
                if dev.resident_uids.get(name) != t.uid:
                    dev.sched.submit(t.profile)
                    dev.resident_uids[name] = t.uid

    def _mark_dead(self, dev: FleetDevice, reason: str) -> None:
        dev.state = D_DEAD
        self.heartbeats.forget(dev.device_id)
        drained = dev.sched.drain()          # the migration hook
        dev.resident_uids.clear()
        self.stats["device_deaths"] += 1
        self._decide("device-dead", device=dev.device_id,
                     reason=f"{reason}; drained {len(drained)} workloads")
        # plan_rescale wiring: displaced elastic-training workloads get
        # a concrete recovery plan (shrunk mesh, same global batch)
        for w in drained:
            t = self._tracked.get(w.name)
            if t is not None and t.train_meta:
                m = t.train_meta
                t.rescale = plan_rescale(
                    m["mesh_shape"], lost_chips=dev.chips,
                    global_batch=m.get("global_batch", 0),
                    num_microbatches=m.get("num_microbatches", 1),
                    current_step=m.get("step", 0))
                self._decide(
                    "rescale-planned", t,
                    reason=f"lost {dev.chips} chip(s) on {dev.device_id}: "
                           f"{m['mesh_shape']} -> {t.rescale.new_shape} "
                           f"({t.rescale.new_chip_count} chips), resume "
                           f"step {t.rescale.restart_step}")

    # ----------------------------- reporting ----------------------- #
    def plan(self) -> FleetPlan:
        """The current fleet state.  Pure read: placements come from the
        last replay (every mutation already replanned)."""
        placements = {}
        for did, members in self._groups.items():
            if not members:
                continue
            gain, _, slows, fracs = self._info[did]
            names = [t.profile.name for t in
                     sorted(members, key=lambda x: x.pos)]
            placements[did] = Placement(
                names, dict(fracs),
                {n: float(slows[n]) for n in names}, True, float(gain))
        return FleetPlan(
            placements=placements,
            queued=[n for n, t in self._tracked.items()
                    if t.state == QUEUED],
            degraded=[n for n, t in self._tracked.items()
                      if t.state == DEGRADED],
            device_states={did: d.state for did, d in self.devices.items()})

    def snapshot(self) -> Dict:
        """Full fleet telemetry: device snapshots (via the per-device
        scheduler hook), workload lifecycle states, queue depths, stats."""
        return {
            "devices": {did: {"state": d.state, "model": d.model.name,
                              "chips": d.chips,
                              "sched": d.sched.snapshot()}
                        for did, d in self.devices.items()},
            "workloads": {n: self.workload_state(n) for n in self._tracked},
            "queued": sum(t.state == QUEUED
                          for t in self._tracked.values()),
            "degraded_workloads": sum(t.state == DEGRADED
                                      for t in self._tracked.values()),
            "decisions": len(self.decisions),
            "stats": dict(self.stats),
        }

    # ----------------------------- internals ----------------------- #
    def _decide(self, action: str, t: Optional[_Tracked] = None,
                device: Optional[str] = None, reason: str = ""
                ) -> AdmissionDecision:
        d = AdmissionDecision(
            seq=self._seq, time=self.clock(), action=action,
            workload=t.profile.name if t is not None else None,
            priority=t.priority if t is not None else None,
            device=device, reason=reason)
        self._seq += 1
        self.decisions.append(d)
        return d

    def _error(self, reason: str) -> None:
        self.stats["errors"] += 1
        self._decide("error", reason=reason)
