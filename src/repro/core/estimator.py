"""Kernel-level interference estimator (paper §5.1's proposed foundation).

Model: concurrent kernels are fluid flows over a vector of shared
resources. Kernel k running at speed s_k <= 1 consumes s_k * u_k[r] of
axis r, where u_k[r] is its full-speed utilization (from KernelProfile).
Speeds are the max-min fair fixed point computed by water-filling:

  repeat:
    find the most oversubscribed axis r* among unfrozen kernels;
    if no axis oversubscribed -> all remaining kernels run at s=1;
    else freeze every unfrozen kernel using r* at the fair speed
         s = available_capacity(r*) / sum(u_k[r*]).

This generalizes all the paper's findings in one mechanism:
  * pitfall 1/2: a kernel with u[issue] ~ 1 (IPC 3.99/4) slows every
    co-runner regardless of its occupancy or arithmetic intensity;
  * §4.3: disjoint-SM kernels still contend on hbm/l2 axes;
  * §4.4.1: smem-axis saturation by a bank-conflicted kernel;
  * §4.4.3: a compute pipeline (mxu/vpu) can saturate before issue does;
  * Fig.3: cache pollution enters through KernelProfile's working-set ->
    hit-fraction discount (cache shared proportionally to working sets).

Capacity scaling: `slot_fraction` models SM partitioning (green contexts /
CUDA_MPS_ACTIVE_THREAD_PERCENTAGE): per-slot axes (mxu/vpu/issue/smem)
scale with the slot share; device-wide axes (hbm/l2/ici) do NOT — exactly
the distinction the paper draws in §4.3.  A fraction at or below
`FRACTION_FLOOR` excludes the member entirely (no demand, no slots,
slowdown +inf), and slot feasibility scales each member's slot need by
its fraction.

Batch execution: the solver is written over dense (scenarios x kernels x
axes) NumPy arrays, so `estimate_batch` solves thousands of colocation
scenarios in one vectorized pass — cheap enough for the scheduling hot
path (the planner's full pairwise matrix, sensitivity sweeps). The scalar
`estimate` is a batch of one, so both paths are numerically identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.backend import get_solver_backend
from repro.core.profile import (KernelProfile, ProfileMatrix,
                                WorkloadProfile, effective_demand_arrays,
                                isolated_time_arrays, utilization_arrays)
from repro.core.resources import AXIS_INDEX, RESOURCE_AXES, DeviceModel
from repro.core.scenario import Scenario, compile_scenarios, scenario_device

PER_SLOT_AXES = ("mxu", "vpu", "issue", "smem")
DEVICE_AXES = ("hbm", "l2", "ici")

# ---- solver floor/tolerance constants, SHARED with the jax backend ---- #
# (repro.core.estimator_jax imports these — never inline the literals in
# either solver, or the oracle and the port can silently drift)
CAP_REMAIN_FLOOR = 1e-9     # floor on a freeze-round's remaining capacity
OVERSUB_RTOL = 1e-9         # an axis is oversubscribed iff load > 1 + this
DEMAND_EPS = 1e-12          # min worst-axis demand to count as an axis user
RATIO_FLOOR = 1e-30         # smem equal-throttle divisor floor (keeps the
                            # vector-wide division defined for done rows)
TIME_EPS = 1e-12            # isolated-time floor in the slowdown ratio
SPEED_FLOOR = 1e-9          # water-filled speed floor in 1/s terms

# f -> 0 semantics: a slot fraction at or below this floor means the
# member is ABSENT (a green context with no slots): it contributes no
# demand, occupies no slots, and its own slowdown is +inf — it makes no
# progress.  Live members keep the documented capacity-scaling behavior;
# the matching 1e-6 clamp inside the solver merely keeps the vectorized
# division defined and can never bite a live member.  (Before this floor
# was defined, a fraction of exactly 0 got ~1e6x inflated demand instead
# of being treated as absent — the k-way fraction search relies on the
# exclusion semantics.)
FRACTION_FLOOR = 1e-6

_N_AXES = len(RESOURCE_AXES)
_PER_SLOT_IDX = np.array([AXIS_INDEX[r] for r in PER_SLOT_AXES])
_SMEM = AXIS_INDEX["smem"]


@dataclass
class ColocationResult:
    speeds: Dict[str, float]            # kernel name -> speed (<=1)
    slowdowns: Dict[str, float]         # kernel name -> 1/speed
    bottleneck: Dict[str, str]          # kernel name -> axis that froze it
    axis_load: Dict[str, float]         # total demanded load per axis
    feasible_slots: bool = True

    def slowdown(self, name: str) -> float:
        return self.slowdowns[name]


@dataclass
class BatchResult:
    """Struct-of-arrays result of one batched solve (padded to the widest
    scenario; `mask` marks real members). Hot-path consumers (planner,
    sensitivity sweeps) read the arrays directly; `result(i)` materializes
    the dict-based ColocationResult view of scenario i."""
    names: Optional[List[List[str]]]    # member names (None when solved
                                        # on the array-only hot path)
    mask: np.ndarray                    # (S, K) bool
    speeds: np.ndarray                  # (S, K)
    slowdowns: np.ndarray               # (S, K)
    bottleneck: np.ndarray              # (S, K) axis index, -1 = none
    axis_load: np.ndarray               # (S, A)
    feasible_slots: np.ndarray          # (S,) bool

    def __len__(self) -> int:
        return len(self.mask)

    def result(self, i: int) -> ColocationResult:
        assert self.names is not None, \
            "solved without names: read the arrays directly"
        ns = self.names[i]
        return ColocationResult(
            speeds={n: float(self.speeds[i, j]) for j, n in enumerate(ns)},
            slowdowns={n: float(self.slowdowns[i, j])
                       for j, n in enumerate(ns)},
            bottleneck={n: (RESOURCE_AXES[b] if (b := int(
                self.bottleneck[i, j])) >= 0 else "none")
                for j, n in enumerate(ns)},
            axis_load={r: float(self.axis_load[i, a])
                       for r, a in AXIS_INDEX.items()},
            feasible_slots=bool(self.feasible_slots[i]),
        )

    def results(self) -> List[ColocationResult]:
        return [self.result(i) for i in range(len(self))]


# queueing inflation: near-saturated ISSUE slots delay every co-runner's
# instructions even when its own demand fits in the leftover (paper Table 2
# knee; calibrated there, validated out-of-sample on pitfall 2). Mild HBM
# latency inflation mirrors Table 1's sub-saturation slowdowns.
_INFLATION = {"issue": (1.05, 4), "hbm": (0.10, 4)}
_INFLATION_MIN_UTIL = 0.01   # below: too small a user to queue behind others
_INFLATION_MAJORITY = 0.5    # at/above this share of the axis load the
                             # kernel is the fluid-limited majority owner


def _gather(pm: ProfileMatrix, members, fractions, mask=None):
    """Pad scenarios to (S, K[, A]) dense arrays; padded rows are zeroed
    so masked sums/maxes are no-ops. An ndarray `members` means padded
    dense width — no padding loop (the planner's hot path); `mask` marks
    the real members (None = every entry real, the uniform-width case)."""
    if isinstance(members, np.ndarray):
        idx = members
        mask = (np.ones(idx.shape, bool) if mask is None
                else np.asarray(mask, bool))
        frac = (np.asarray(fractions, np.float64) if fractions is not None
                else np.ones(idx.shape, np.float64))
        # padded entries carry frac 1.0 so the slot-scale division is a
        # no-op on them (compile_scenarios pads this way already; guard
        # direct callers handing their own mask + fraction arrays)
        if not mask.all():
            frac = np.where(mask, frac, 1.0)
    else:
        S = len(members)
        K = max(len(m) for m in members)
        idx = np.zeros((S, K), np.int64)
        mask = np.zeros((S, K), bool)
        frac = np.ones((S, K), np.float64)
        for s, (m, f) in enumerate(zip(members, fractions)):
            idx[s, :len(m)] = m
            mask[s, :len(m)] = True
            frac[s, :len(m)] = f
    demand = pm.demand[idx] * mask[:, :, None]
    duration = pm.duration[idx] * mask
    ws = pm.cache_working_set[idx] * mask
    hit = pm.cache_hit_fraction[idx] * mask
    slots = pm.slots_needed[idx] * mask
    return idx, mask, frac, demand, duration, ws, hit, slots


def solve_batch(pm: ProfileMatrix, members, dev: DeviceModel,
                fractions=None, names: Optional[List[List[str]]] = None,
                *, mask=None) -> BatchResult:
    """Vectorized core: solve S colocation scenarios, each a list of row
    indices into `pm` (or a padded dense (S, K) ndarray with an optional
    bool `mask` marking real members — no mask means every entry is
    real), with optional per-member slot fractions. `names` feeds the
    dict-view `result(i)`; array-only consumers may omit it.

    Executes on the active solver backend (`repro.core.backend`): the
    NumPy oracle below, or the jax.jit port (`repro.core.estimator_jax`)
    — identical results at 1e-9, gated in CI by the bench_planner solver
    parity sweep."""
    if len(members) == 0:
        z2 = np.zeros((0, 0))
        return BatchResult(names if names is not None else [],
                           np.zeros((0, 0), bool), z2, z2,
                           np.zeros((0, 0), np.int64),
                           np.zeros((0, _N_AXES)), np.zeros(0, bool))
    if fractions is None and not isinstance(members, np.ndarray):
        fractions = [[1.0] * len(m) for m in members]
    if names is None and not isinstance(members, np.ndarray):
        names = [[pm.names[i] for i in m] for m in members]
    _, mask, frac, demand, duration, ws, hit, slots = _gather(
        pm, members, fractions, mask)
    S, K = mask.shape
    if K > 0 and get_solver_backend() == "jax":
        from repro.core import estimator_jax
        speeds, slowdowns, frozen, axis_load, feasible = \
            estimator_jax.solve_gathered(mask, frac, demand, duration, ws,
                                         hit, slots, dev)
        return BatchResult(names, mask, speeds, slowdowns, frozen,
                           axis_load, feasible)
    # members at or below the exclusion floor are absent (see
    # FRACTION_FLOOR): zero their inputs so they neither contend nor
    # occupy slots; their own slowdown is patched to +inf at the end
    excluded = mask & (frac <= FRACTION_FLOOR)
    present = mask & ~excluded
    if excluded.any():
        demand = np.where(present[:, :, None], demand, 0.0)
        duration = np.where(present, duration, 0.0)
        ws = np.where(present, ws, 0.0)
        hit = np.where(present, hit, 0.0)
        slots = np.where(present, slots, 0.0)
    if K == 0:                    # every scenario empty: nothing contends
        z = np.zeros((S, 0))
        return BatchResult(names, mask, z, z, np.zeros((S, 0), np.int64),
                           np.zeros((S, _N_AXES)), np.ones(S, bool))
    cap_vec = dev.capacity_vector()

    # cache model: isolated residency is proportional (min(1, C/ws));
    # colocated STREAMING residency has a thrash cliff — once the combined
    # working set exceeds capacity, interleaved streams evict each other
    # before reuse (paper Fig. 3's 16MB peak), so hits collapse.
    cache_cap = dev.cache_capacity
    total_ws = ws.sum(1)
    resident_col = np.where(total_ws > cache_cap, 0.0, 1.0)
    nk = present.sum(1)
    has_ws = ws > 0
    share = np.where(
        has_ws & (nk[:, None] > 1), resident_col[:, None],
        np.where(has_ws, np.minimum(1.0, cache_cap / np.maximum(ws, 1.0)),
                 1.0))

    eff_col = effective_demand_arrays(demand, ws, hit, cache_cap, share)
    t_col = isolated_time_arrays(eff_col, duration, cap_vec)
    eff_iso = effective_demand_arrays(demand, ws, hit, cache_cap,
                                      np.ones_like(share))
    t_iso = isolated_time_arrays(eff_iso, duration, cap_vec)
    u = utilization_arrays(eff_col, t_col, cap_vec)
    # restricting a kernel to a slot fraction: per-slot axes capacity
    # seen by that kernel shrinks -> its relative demand grows.  Live
    # fractions are > FRACTION_FLOOR (smaller ones were excluded above),
    # so the clamp only keeps the division defined for excluded rows.
    slot_scale = np.where(frac < 1.0, np.maximum(frac, FRACTION_FLOOR), 1.0)
    u[:, :, _PER_SLOT_IDX] = u[:, :, _PER_SLOT_IDX] / slot_scale[:, :, None]

    axis_load = u.sum(1)

    # per-axis max-min water-filling: on each oversubscribed axis, only
    # kernels demanding MORE than the fair rate are throttled (a 0.14-IPC
    # copy keeps its slots next to a 3.99-IPC hog; both hogs split evenly).
    # All scenarios advance one freeze-round per iteration; finished ones
    # are masked out by `done`.
    speeds = np.ones((S, K))
    active = present.copy()
    frozen = np.full((S, K), -1, np.int64)
    used = np.zeros((S, _N_AXES))
    done = np.zeros(S, bool)
    rows = np.arange(S)
    for _ in range(K + _N_AXES):
        dem = (u * (speeds * active)[:, :, None]).sum(1)
        cap_rem = np.maximum(1.0 - used, CAP_REMAIN_FLOOR)
        ratio = dem / cap_rem
        worst = ratio.argmax(1)
        worst_ratio = ratio[rows, worst]
        done |= worst_ratio <= 1.0 + OVERSUB_RTOL
        if done.all():
            break
        live = ~done
        u_w = np.take_along_axis(u, worst[:, None, None], axis=2)[:, :, 0]
        d = speeds * u_w

        # smem: bank-conflict serialization throttles EVERY user equally
        # (paper Fig. 4: even low-smem-util GEMMs slow down)
        is_smem = live & (worst == _SMEM)
        if is_smem.any():
            users = active & (d > DEMAND_EPS) & is_smem[:, None]
            # only consumed where is_smem (worst_ratio > 1); the floor just
            # keeps the vector-wide division defined for finished scenarios
            s_eq = 1.0 / np.maximum(worst_ratio, RATIO_FLOOR)
            speeds = np.where(users, speeds * s_eq[:, None], speeds)
            used += (u * (speeds * users)[:, :, None]).sum(1)
            frozen = np.where(users, _SMEM, frozen)
            active &= ~users

        # max-min rate cap theta on worst_axis: sum min(d_n, theta) = cap.
        # Sort eligible demands ascending; theta is the first even share
        # breached after granting all smaller demands in full.
        is_mm = live & (worst != _SMEM)
        if is_mm.any():
            elig = active & (d > DEMAND_EPS) & is_mm[:, None]
            cap_w = cap_rem[rows, worst]
            ds = np.where(elig, d, np.inf)
            order = np.sort(ds, axis=1)
            finite = np.isfinite(order)
            vals = np.where(finite, order, 0.0)
            csum = np.cumsum(vals, axis=1)
            m = elig.sum(1)
            pos = np.arange(K)[None, :]
            even = (cap_w[:, None] - (csum - vals)) / np.maximum(
                m[:, None] - pos, 1)
            breach = finite & (order > even) & (pos < m[:, None])
            has_theta = breach.any(1) & is_mm
            theta = even[rows, breach.argmax(1)]
            # no breach -> every user fits under the fair share: nothing
            # left to throttle in this scenario
            done |= is_mm & ~has_theta
            throttled = elig & has_theta[:, None] & (d > theta[:, None])
            speeds = np.where(throttled,
                              speeds * (theta[:, None]
                                        / np.where(d > 0, d, 1.0)),
                              speeds)
            used += (u * (speeds * throttled)[:, :, None]).sum(1)
            frozen = np.where(throttled, worst[:, None], frozen)
            active &= ~throttled

    # queueing inflation on near-saturated latency-sensitive axes: applies
    # to MINORITY users of the axis (the majority owner is fluid-limited)
    base = (t_col / np.maximum(t_iso, TIME_EPS)) / np.maximum(speeds,
                                                              SPEED_FLOOR)
    infl = np.ones((S, K))
    for axis, (gamma, p) in _INFLATION.items():
        ai = AXIS_INDEX[axis]
        u_ax = u[:, :, ai]
        rho = np.minimum(1.0, (speeds * u_ax).sum(1))
        skip = ((frozen == ai) | (u_ax <= _INFLATION_MIN_UTIL)
                | (u_ax >= _INFLATION_MAJORITY
                   * np.maximum(rho, SPEED_FLOOR)[:, None]))
        infl += np.where(~skip & present, gamma * rho[:, None] ** p, 0.0)
    slowdowns = base * infl
    if excluded.any():
        speeds = np.where(excluded, 0.0, speeds)
        slowdowns = np.where(excluded, np.inf, slowdowns)

    # slot feasibility is fraction-aware: a partitioned member occupies
    # only its slice of the SM partition, so its slot need scales with
    # its fraction (excluded members were already zeroed above)
    tot_slots = (slots * np.minimum(frac, 1.0)).sum(1)
    return BatchResult(
        names=names,
        mask=mask,
        speeds=speeds,
        slowdowns=slowdowns,
        bottleneck=frozen,
        axis_load=axis_load,
        feasible_slots=(tot_slots <= dev.n_slots) | (tot_slots == 0),
    )


def solve_scenarios(scenarios: Sequence[Scenario],
                    dev: Optional[DeviceModel] = None) -> BatchResult:
    """Solve a batch of `Scenario` objects (the shared query currency —
    see repro.core.scenario) in one vectorized pass.

    Members are ordered victims-first, so scenario ``s``'s victim
    slowdowns are ``result.slowdowns[s, :scenarios[s].n_victims]``.
    Results are positional, so duplicate kernel names (or the same
    profile colocated with itself) are fine — unlike the name-keyed
    `estimate_batch`.
    """
    scenarios = list(scenarios)
    if not scenarios:
        # dev is irrelevant for an empty batch; solve_batch returns the
        # canonical empty BatchResult before ever touching it
        return solve_batch(ProfileMatrix.from_profiles([]), [], dev)
    dev = scenario_device(scenarios, dev)
    comp = compile_scenarios(scenarios)
    return solve_batch(comp.pm, comp.members, dev, comp.fractions,
                       mask=comp.mask)


def _compile_scenarios(scenarios: Sequence[Sequence[KernelProfile]],
                       slot_fractions: Optional[
                           Sequence[Optional[Dict[str, float]]]]):
    """Dedup profiles by identity into one ProfileMatrix + index lists."""
    row_of: Dict[int, int] = {}
    profiles: List[KernelProfile] = []
    members: List[List[int]] = []
    fractions: List[List[float]] = []
    names: List[List[str]] = []
    if slot_fractions is None:
        slot_fractions = [None] * len(scenarios)
    for sc, sf in zip(scenarios, slot_fractions):
        sf = sf or {}
        m, f, ns = [], [], []
        for k in sc:
            r = row_of.get(id(k))
            if r is None:
                r = row_of[id(k)] = len(profiles)
                profiles.append(k)
            m.append(r)
            f.append(sf.get(k.name, 1.0))
            ns.append(k.name)
        if len(set(ns)) != len(ns):
            # name-keyed results cannot represent duplicate members (the
            # seed silently collapsed them into one kernel); the
            # positional solve_batch API handles same-profile colocation
            raise ValueError(f"duplicate kernel names in scenario: {ns}")
        members.append(m)
        fractions.append(f)
        names.append(ns)
    return ProfileMatrix.from_profiles(profiles), members, fractions, names


def estimate_batch(scenarios: Sequence[Sequence[KernelProfile]],
                   dev: DeviceModel,
                   slot_fractions: Optional[
                       Sequence[Optional[Dict[str, float]]]] = None
                   ) -> List[ColocationResult]:
    """Solve many colocation scenarios in one vectorized pass.

    scenarios[i] is the kernel set of scenario i; slot_fractions[i] is its
    optional per-kernel-name slot-fraction dict (see `estimate`). Returns
    one ColocationResult per scenario, identical to calling `estimate` on
    each scenario individually.

    Kernel names must be unique within a scenario (results are keyed by
    name). To colocate several instances of the same profile, use
    `solve_batch` with repeated row indices — one row per instance.
    """
    if not len(scenarios):
        return []
    if slot_fractions is not None and len(slot_fractions) != len(scenarios):
        raise ValueError(
            f"slot_fractions has {len(slot_fractions)} entries for "
            f"{len(scenarios)} scenarios")
    pm, members, fractions, names = _compile_scenarios(
        scenarios, slot_fractions)
    return solve_batch(pm, members, dev, fractions, names).results()


def estimate(kernels: Sequence[KernelProfile], dev: DeviceModel,
             slot_fraction: Optional[Dict[str, float]] = None
             ) -> ColocationResult:
    """Steady-state speeds + total slowdowns for concurrent kernels.

    slowdown_k = (t_col_k / t_iso_k) / s_k x inflation, where t_col uses
    the COLOCATED cache share (pollution grows demand), s_k is the
    water-filled speed, and inflation is the near-saturation queueing term.

    Thin wrapper over `estimate_batch` with a single scenario — the batch
    path is the only solver, so scalar and batched results are identical.
    """
    return estimate_batch([list(kernels)], dev, [slot_fraction])[0]


def pairwise_slowdown(a: KernelProfile, b: KernelProfile, dev: DeviceModel,
                      slot_fraction: Optional[Dict[str, float]] = None
                      ) -> Tuple[float, float]:
    r = estimate([a, b], dev, slot_fraction)
    return r.slowdown(a.name), r.slowdown(b.name)


def colocation_speedup(a: KernelProfile, b: KernelProfile,
                       dev: DeviceModel) -> float:
    """Paper Table 3 metric: sequential time / colocated makespan."""
    ta, tb = a.isolated_time(dev), b.isolated_time(dev)
    r = estimate([a, b], dev)
    # fluid makespan: run colocated until the shorter finishes, remainder solo
    ra = ta / max(r.speeds[a.name], 1e-9)
    rb = tb / max(r.speeds[b.name], 1e-9)
    first = min(ra, rb)
    if ra <= rb:
        done_frac = first * r.speeds[b.name] / tb
        makespan = first + (1 - done_frac) * tb
    else:
        done_frac = first * r.speeds[a.name] / ta
        makespan = first + (1 - done_frac) * ta
    return (ta + tb) / makespan


def workload_slowdown(w: WorkloadProfile, others: Sequence[KernelProfile],
                      dev: DeviceModel,
                      slot_fraction: Optional[Dict[str, float]] = None
                      ) -> float:
    """Average slowdown of workload `w` when each of its kernels runs
    against the (steady) background kernels — per-kernel granularity.
    One `Scenario` per kernel of `w` (victim = the kernel, background =
    the steady co-runners), solved positionally in one batch so a kernel
    sharing a background kernel's name still contends physically instead
    of tripping the name-keyed API's duplicate check."""
    others = tuple(others)
    if not w.kernels:
        return 0.0      # seed semantics: 0-time workload -> 0/1e-12
    br = solve_scenarios([Scenario((k,), others, slot_fraction)
                          for k in w.kernels], dev)
    tot_iso = tot_col = 0.0
    for k, slow in zip(w.kernels, br.slowdowns[:, 0]):
        t = k.isolated_time(dev) * k.duration_weight
        tot_iso += t
        tot_col += t * float(slow)
    return tot_col / max(tot_iso, 1e-12)
