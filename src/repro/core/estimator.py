"""Kernel-level interference estimator (paper §5.1's proposed foundation).

Model: concurrent kernels are fluid flows over a vector of shared
resources. Kernel k running at speed s_k <= 1 consumes s_k * u_k[r] of
axis r, where u_k[r] is its full-speed utilization (from KernelProfile).
Speeds are the max-min fair fixed point computed by water-filling:

  repeat:
    find the most oversubscribed axis r* among unfrozen kernels;
    if no axis oversubscribed -> all remaining kernels run at s=1;
    else freeze every unfrozen kernel using r* at the fair speed
         s = available_capacity(r*) / sum(u_k[r*]).

This generalizes all the paper's findings in one mechanism:
  * pitfall 1/2: a kernel with u[issue] ~ 1 (IPC 3.99/4) slows every
    co-runner regardless of its occupancy or arithmetic intensity;
  * §4.3: disjoint-SM kernels still contend on hbm/l2 axes;
  * §4.4.1: smem-axis saturation by a bank-conflicted kernel;
  * §4.4.3: a compute pipeline (mxu/vpu) can saturate before issue does;
  * Fig.3: cache pollution enters through KernelProfile's working-set ->
    hit-fraction discount (cache shared proportionally to working sets).

Capacity scaling: `slot_fraction` models SM partitioning (green contexts /
CUDA_MPS_ACTIVE_THREAD_PERCENTAGE): per-slot axes (mxu/vpu/issue/smem)
scale with the slot share; device-wide axes (hbm/l2/ici) do NOT — exactly
the distinction the paper draws in §4.3.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import RESOURCE_AXES, DeviceModel

PER_SLOT_AXES = ("mxu", "vpu", "issue", "smem")
DEVICE_AXES = ("hbm", "l2", "ici")


@dataclass
class ColocationResult:
    speeds: Dict[str, float]            # kernel name -> speed (<=1)
    slowdowns: Dict[str, float]         # kernel name -> 1/speed
    bottleneck: Dict[str, str]          # kernel name -> axis that froze it
    axis_load: Dict[str, float]         # total demanded load per axis
    feasible_slots: bool = True

    def slowdown(self, name: str) -> float:
        return self.slowdowns[name]


# queueing inflation: near-saturated ISSUE slots delay every co-runner's
# instructions even when its own demand fits in the leftover (paper Table 2
# knee; calibrated there, validated out-of-sample on pitfall 2). Mild HBM
# latency inflation mirrors Table 1's sub-saturation slowdowns.
_INFLATION = {"issue": (1.05, 4), "hbm": (0.10, 4)}


def _utilizations(kernels: Sequence[KernelProfile], dev: DeviceModel,
                  slot_fraction: Dict[str, float]) -> Dict[str, Dict[str, float]]:
    total_ws = sum(k.cache_working_set for k in kernels)
    us = {}
    for k in kernels:
        share = (k.cache_working_set / total_ws
                 if total_ws > dev.cache_capacity and k.cache_working_set
                 else 1.0)
        u = k.utilization(dev, cache_share=share)
        frac = slot_fraction.get(k.name, 1.0)
        # restricting a kernel to a slot fraction: per-slot axes capacity
        # seen by that kernel shrinks -> its relative demand grows
        if frac < 1.0:
            for r in PER_SLOT_AXES:
                u[r] = u[r] / max(frac, 1e-6)
        us[k.name] = u
    return us


def estimate(kernels: Sequence[KernelProfile], dev: DeviceModel,
             slot_fraction: Optional[Dict[str, float]] = None
             ) -> ColocationResult:
    """Steady-state speeds + total slowdowns for concurrent kernels.

    slowdown_k = (t_col_k / t_iso_k) / s_k x inflation, where t_col uses
    the COLOCATED cache share (pollution grows demand), s_k is the
    water-filled speed, and inflation is the near-saturation queueing term.
    """
    slot_fraction = slot_fraction or {}
    names = [k.name for k in kernels]
    # cache model: isolated residency is proportional (min(1, C/ws));
    # colocated STREAMING residency has a thrash cliff — once the combined
    # working set exceeds capacity, interleaved streams evict each other
    # before reuse (paper Fig. 3's 16MB peak), so hits collapse.
    total_ws = sum(k.cache_working_set for k in kernels)
    resident_col = 0.0 if total_ws > dev.cache_capacity else 1.0
    us = {}
    t_iso, t_col = {}, {}
    for k in kernels:
        share = resident_col if (len(kernels) > 1 and k.cache_working_set) \
            else min(1.0, dev.cache_capacity / max(k.cache_working_set, 1.0)) \
            if k.cache_working_set else 1.0
        u = k.utilization(dev, cache_share=share)
        frac = slot_fraction.get(k.name, 1.0)
        if frac < 1.0:
            for r in PER_SLOT_AXES:
                u[r] = u[r] / max(frac, 1e-6)
        us[k.name] = u
        t_iso[k.name] = k.isolated_time(dev, cache_share=1.0)
        t_col[k.name] = k.isolated_time(dev, cache_share=share)

    speeds: Dict[str, float] = {n: 1.0 for n in names}
    frozen: Dict[str, str] = {n: "none" for n in names}
    axis_load = {r: sum(us[n][r] for n in names) for r in RESOURCE_AXES}

    # per-axis max-min water-filling: on each oversubscribed axis, only
    # kernels demanding MORE than the fair rate are throttled (a 0.14-IPC
    # copy keeps its slots next to a 3.99-IPC hog; both hogs split evenly)
    active = set(names)
    used = {r: 0.0 for r in RESOURCE_AXES}
    for _ in range(len(names) + len(RESOURCE_AXES)):
        worst_axis, worst_ratio = None, 1.0 + 1e-9
        for r in RESOURCE_AXES:
            dem = sum(speeds[n] * us[n][r] for n in active)
            cap = max(1.0 - used[r], 1e-9)
            if dem / cap > worst_ratio:
                worst_axis, worst_ratio = r, dem / cap
        if worst_axis is None:
            break
        if worst_axis == "smem":
            # bank-conflict serialization throttles EVERY user equally
            # (paper Fig. 4: even low-smem-util GEMMs slow down)
            s = 1.0 / worst_ratio
            for n in list(active):
                if speeds[n] * us[n][worst_axis] > 1e-12:
                    speeds[n] *= s
                    frozen[n] = worst_axis
                    active.discard(n)
                    for r in RESOURCE_AXES:
                        used[r] += speeds[n] * us[n][r]
            continue
        # max-min rate cap theta on worst_axis: sum min(u_n, theta) = cap
        users = sorted(active, key=lambda n: speeds[n] * us[n][worst_axis])
        cap = max(1.0 - used[worst_axis], 1e-9)
        remaining_cap = cap
        remaining_users = [n for n in users
                           if speeds[n] * us[n][worst_axis] > 1e-12]
        theta = None
        for idx, n in enumerate(remaining_users):
            d = speeds[n] * us[n][worst_axis]
            even = remaining_cap / (len(remaining_users) - idx)
            if d <= even:
                remaining_cap -= d
            else:
                theta = even
                break
        if theta is None:
            break
        for n in remaining_users:
            d = speeds[n] * us[n][worst_axis]
            if d > theta:
                scale = theta / d
                speeds[n] *= scale
                frozen[n] = worst_axis
                active.discard(n)
                for r in RESOURCE_AXES:
                    used[r] += speeds[n] * us[n][r]

    # queueing inflation on near-saturated latency-sensitive axes: applies
    # to MINORITY users of the axis (the majority owner is fluid-limited)
    slowdowns = {}
    for n in names:
        base = (t_col[n] / max(t_iso[n], 1e-12)) / max(speeds[n], 1e-9)
        infl = 1.0
        for axis, (gamma, p) in _INFLATION.items():
            u_n = us[n].get(axis, 0.0)
            rho = min(1.0, sum(speeds[m] * us[m][axis] for m in names))
            if (frozen.get(n) == axis or u_n <= 0.01
                    or u_n >= 0.5 * max(rho, 1e-9)):
                continue
            infl += gamma * rho ** p
        slowdowns[n] = base * infl

    slots_needed = sum(k.slots_needed for k in kernels)
    return ColocationResult(
        speeds=speeds,
        slowdowns=slowdowns,
        bottleneck=frozen,
        axis_load=axis_load,
        feasible_slots=slots_needed <= dev.n_slots or slots_needed == 0,
    )


def pairwise_slowdown(a: KernelProfile, b: KernelProfile, dev: DeviceModel,
                      slot_fraction: Optional[Dict[str, float]] = None
                      ) -> Tuple[float, float]:
    r = estimate([a, b], dev, slot_fraction)
    return r.slowdown(a.name), r.slowdown(b.name)


def colocation_speedup(a: KernelProfile, b: KernelProfile,
                       dev: DeviceModel) -> float:
    """Paper Table 3 metric: sequential time / colocated makespan."""
    ta, tb = a.isolated_time(dev), b.isolated_time(dev)
    r = estimate([a, b], dev)
    # fluid makespan: run colocated until the shorter finishes, remainder solo
    ra = ta / max(r.speeds[a.name], 1e-9)
    rb = tb / max(r.speeds[b.name], 1e-9)
    first = min(ra, rb)
    if ra <= rb:
        done_frac = first * r.speeds[b.name] / tb
        makespan = first + (1 - done_frac) * tb
    else:
        done_frac = first * r.speeds[a.name] / ta
        makespan = first + (1 - done_frac) * ta
    return (ta + tb) / makespan


def workload_slowdown(w: WorkloadProfile, others: Sequence[KernelProfile],
                      dev: DeviceModel,
                      slot_fraction: Optional[Dict[str, float]] = None
                      ) -> float:
    """Average slowdown of workload `w` when each of its kernels runs
    against the (steady) background kernels — per-kernel granularity."""
    tot_iso = tot_col = 0.0
    for k in w.kernels:
        t = k.isolated_time(dev) * k.duration_weight
        r = estimate([k, *others], dev, slot_fraction)
        tot_iso += t
        tot_col += t * r.slowdown(k.name)
    return tot_col / max(tot_iso, 1e-12)
