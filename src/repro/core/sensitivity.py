"""Sensitivity quantification — the paper's §4 methodology as a library.

For a workload kernel K and each resource axis r, colocate K with a
calibrated stressor that consumes intensity lambda on r (and nothing
else), sweep lambda in [0, 1], and record K's predicted slowdown. The
resulting per-axis curves are the workload's *interference fingerprint*:
the multi-dimensional replacement for occupancy/arithmetic-intensity
scalars (pitfalls 1-2).

On real hardware the same sweep runs the Pallas stressor kernels
(repro.kernels.stressors) next to the workload; here the estimator
provides the predicted curves, and benchmarks/ validates the estimator
against the paper's measured GPU numbers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.core.estimator import estimate
from repro.core.profile import KernelProfile
from repro.core.resources import RESOURCE_AXES, DeviceModel


def stressor(axis: str, intensity: float, dev: DeviceModel,
             working_set: float = 0.0) -> KernelProfile:
    """Synthetic kernel consuming `intensity` of axis capacity.

    Maps 1:1 to the Pallas microbenchmarks: mxu -> stress_mxu, vpu/issue
    -> stress_vpu(ilp), hbm/l2 -> stress_hbm, smem -> stress_vmem.
    """
    demand = {r: 0.0 for r in RESOURCE_AXES}
    demand[axis] = intensity * dev.capacity(axis)
    # duration=1: the stressor occupies exactly `intensity` of the axis
    return KernelProfile(f"stress:{axis}:{intensity:.2f}", demand=demand,
                         duration=1.0, cache_working_set=working_set)


@dataclass
class SensitivityReport:
    kernel: str
    curves: Dict[str, List[float]]       # axis -> slowdown per lambda
    lambdas: List[float]
    scores: Dict[str, float]             # axis -> slowdown at lambda=0.9

    def ranked(self) -> List[str]:
        return sorted(self.scores, key=lambda a: -self.scores[a])

    def dominant(self) -> str:
        return self.ranked()[0]


def sensitivity(kernel: KernelProfile, dev: DeviceModel,
                lambdas: Sequence[float] = (0.1, 0.25, 0.5, 0.75, 0.9),
                axes: Sequence[str] = RESOURCE_AXES) -> SensitivityReport:
    curves: Dict[str, List[float]] = {}
    for axis in axes:
        row = []
        for lam in lambdas:
            st = stressor(axis, lam, dev)
            r = estimate([kernel, st], dev)
            row.append(r.slowdown(kernel.name))
        curves[axis] = row
    scores = {a: curves[a][-1] for a in axes}
    return SensitivityReport(kernel.name, curves, list(lambdas), scores)


def cache_pollution_curve(kernel: KernelProfile, dev: DeviceModel,
                          polluter_ws: Sequence[float]) -> List[float]:
    """Paper Fig. 3: slowdown of `kernel` vs a polluter's working set."""
    out = []
    for ws in polluter_ws:
        pol = KernelProfile(
            "polluter",
            demand={**{r: 0.0 for r in RESOURCE_AXES},
                    "hbm": dev.hbm_bw * 0.5, "l2": dev.l2_bw * 0.5},
            cache_working_set=ws, cache_hit_fraction=1.0)
        r = estimate([kernel, pol], dev)
        out.append(r.slowdown(kernel.name))
    return out
