"""Sensitivity quantification — the paper's §4 methodology as a library.

For a workload kernel K and each resource axis r, colocate K with a
calibrated stressor that consumes intensity lambda on r (and nothing
else), sweep lambda in [0, 1], and record K's predicted slowdown. The
resulting per-axis curves are the workload's *interference fingerprint*:
the multi-dimensional replacement for occupancy/arithmetic-intensity
scalars (pitfalls 1-2).

On real hardware the same sweep runs the Pallas stressor kernels
(repro.kernels.stressors) next to the workload; here the estimator
provides the predicted curves, and benchmarks/ validates the estimator
against the paper's measured GPU numbers.

A full fingerprint (axes x lambda grid) is ONE batched estimator solve
(`sensitivity_batch` fingerprints many kernels in a single pass).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.core.estimator import solve_scenarios
from repro.core.fracsearch import member_slowdowns
from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import RESOURCE_AXES, DeviceModel
from repro.core.scenario import Scenario, group_victim_scenarios


def stressor(axis: str, intensity: float, dev: DeviceModel,
             working_set: float = 0.0) -> KernelProfile:
    """Synthetic kernel consuming `intensity` of axis capacity.

    Maps 1:1 to the Pallas microbenchmarks: mxu -> stress_mxu, vpu/issue
    -> stress_vpu(ilp), hbm/l2 -> stress_hbm, smem -> stress_vmem.
    """
    demand = {r: 0.0 for r in RESOURCE_AXES}
    demand[axis] = intensity * dev.capacity(axis)
    # duration=1: the stressor occupies exactly `intensity` of the axis
    return KernelProfile(f"stress:{axis}:{intensity:.2f}", demand=demand,
                         duration=1.0, cache_working_set=working_set)


@dataclass
class SensitivityReport:
    kernel: str
    curves: Dict[str, List[float]]       # axis -> slowdown per lambda
    lambdas: List[float]
    scores: Dict[str, float]             # axis -> slowdown at lambda=0.9

    def ranked(self) -> List[str]:
        return sorted(self.scores, key=lambda a: -self.scores[a])

    def dominant(self) -> str:
        return self.ranked()[0]


def sensitivity_batch(kernels: Sequence[KernelProfile], dev: DeviceModel,
                      lambdas: Sequence[float] = (0.1, 0.25, 0.5, 0.75, 0.9),
                      axes: Sequence[str] = RESOURCE_AXES
                      ) -> List[SensitivityReport]:
    """Fingerprint every kernel in one batched solve: scenarios are the
    (kernel x axis x lambda) grid, each pairing the kernel with the
    matching single-axis stressor."""
    kernels = list(kernels)
    if not kernels:
        return []
    stressors = [stressor(axis, lam, dev) for axis in axes for lam in lambdas]
    # one Scenario per (kernel, stressor) grid point — kernels dedup by
    # identity, so the matrix still has one row per distinct profile
    br = solve_scenarios([Scenario((k,), (st,)) for k in kernels
                          for st in stressors], dev)
    slow = br.slowdowns[:, 0].reshape(len(kernels), len(axes), len(lambdas))
    reports = []
    for ki, k in enumerate(kernels):
        curves = {a: [float(s) for s in slow[ki, ai]]
                  for ai, a in enumerate(axes)}
        scores = {a: curves[a][-1] for a in axes}
        reports.append(SensitivityReport(k.name, curves, list(lambdas),
                                         scores))
    return reports


def sensitivity(kernel: KernelProfile, dev: DeviceModel,
                lambdas: Sequence[float] = (0.1, 0.25, 0.5, 0.75, 0.9),
                axes: Sequence[str] = RESOURCE_AXES) -> SensitivityReport:
    return sensitivity_batch([kernel], dev, lambdas, axes)[0]


def partition_curve(workloads: Sequence[WorkloadProfile], dev: DeviceModel,
                    member: int, fractions: Sequence[float]
                    ) -> Dict[str, List[float]]:
    """Paper §5.3 sweep: every member's workload slowdown as ``member``'s
    slot fraction varies (the others split the complement evenly) — the
    one-dimensional ray of the simplex the legacy fixed grid explored,
    exposed as a diagnostic for the k-way fraction search.  The whole
    (fractions x member-kernel) grid is ONE batched solve.
    """
    works = list(workloads)
    fractions = list(fractions)
    if not works or not fractions:
        return {}
    if not 0 <= member < len(works):
        raise ValueError(f"member index {member} out of range for "
                         f"{len(works)} workloads")
    reps = {w.name: w.representative_kernel(dev) for w in works}
    rest = max(len(works) - 1, 1)
    scenarios = []
    for f in fractions:
        sf = {w.name: (f if i == member else (1.0 - f) / rest)
              for i, w in enumerate(works)}
        scenarios.extend(group_victim_scenarios(works, reps, sf))
    br = solve_scenarios(scenarios, dev)
    rows_per = sum(len(w.kernels) for w in works)
    curves: Dict[str, List[float]] = {w.name: [] for w in works}
    for fi in range(len(fractions)):
        slows = member_slowdowns(
            works, dev, br.slowdowns[fi * rows_per:(fi + 1) * rows_per, 0])
        for n, s in slows.items():
            curves[n].append(float(s))
    return curves


def cache_pollution_curve(kernel: KernelProfile, dev: DeviceModel,
                          polluter_ws: Sequence[float]) -> List[float]:
    """Paper Fig. 3: slowdown of `kernel` vs a polluter's working set —
    the whole sweep is one batched solve."""
    polluter_ws = list(polluter_ws)
    if not polluter_ws:
        return []
    base_demand = {**{r: 0.0 for r in RESOURCE_AXES},
                   "hbm": dev.hbm_bw * 0.5, "l2": dev.l2_bw * 0.5}
    polluters = [KernelProfile("polluter", demand=base_demand,
                               cache_working_set=ws, cache_hit_fraction=1.0)
                 for ws in polluter_ws]
    br = solve_scenarios([Scenario((kernel,), (p,)) for p in polluters], dev)
    return [float(s) for s in br.slowdowns[:, 0]]
