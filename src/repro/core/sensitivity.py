"""Sensitivity quantification — the paper's §4 methodology as a library.

For a workload kernel K and each resource axis r, colocate K with a
calibrated stressor that consumes intensity lambda on r (and nothing
else), sweep lambda in [0, 1], and record K's predicted slowdown. The
resulting per-axis curves are the workload's *interference fingerprint*:
the multi-dimensional replacement for occupancy/arithmetic-intensity
scalars (pitfalls 1-2).

On real hardware the same sweep runs the Pallas stressor kernels
(repro.kernels.stressors) next to the workload; here the estimator
provides the predicted curves, and benchmarks/ validates the estimator
against the paper's measured GPU numbers.

A full fingerprint (axes x lambda grid) is ONE batched estimator solve
(`sensitivity_batch` fingerprints many kernels in a single pass).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.core.estimator import solve_scenarios
from repro.core.profile import KernelProfile
from repro.core.resources import RESOURCE_AXES, DeviceModel
from repro.core.scenario import Scenario


def stressor(axis: str, intensity: float, dev: DeviceModel,
             working_set: float = 0.0) -> KernelProfile:
    """Synthetic kernel consuming `intensity` of axis capacity.

    Maps 1:1 to the Pallas microbenchmarks: mxu -> stress_mxu, vpu/issue
    -> stress_vpu(ilp), hbm/l2 -> stress_hbm, smem -> stress_vmem.
    """
    demand = {r: 0.0 for r in RESOURCE_AXES}
    demand[axis] = intensity * dev.capacity(axis)
    # duration=1: the stressor occupies exactly `intensity` of the axis
    return KernelProfile(f"stress:{axis}:{intensity:.2f}", demand=demand,
                         duration=1.0, cache_working_set=working_set)


@dataclass
class SensitivityReport:
    kernel: str
    curves: Dict[str, List[float]]       # axis -> slowdown per lambda
    lambdas: List[float]
    scores: Dict[str, float]             # axis -> slowdown at lambda=0.9

    def ranked(self) -> List[str]:
        return sorted(self.scores, key=lambda a: -self.scores[a])

    def dominant(self) -> str:
        return self.ranked()[0]


def sensitivity_batch(kernels: Sequence[KernelProfile], dev: DeviceModel,
                      lambdas: Sequence[float] = (0.1, 0.25, 0.5, 0.75, 0.9),
                      axes: Sequence[str] = RESOURCE_AXES
                      ) -> List[SensitivityReport]:
    """Fingerprint every kernel in one batched solve: scenarios are the
    (kernel x axis x lambda) grid, each pairing the kernel with the
    matching single-axis stressor."""
    kernels = list(kernels)
    if not kernels:
        return []
    stressors = [stressor(axis, lam, dev) for axis in axes for lam in lambdas]
    # one Scenario per (kernel, stressor) grid point — kernels dedup by
    # identity, so the matrix still has one row per distinct profile
    br = solve_scenarios([Scenario((k,), (st,)) for k in kernels
                          for st in stressors], dev)
    slow = br.slowdowns[:, 0].reshape(len(kernels), len(axes), len(lambdas))
    reports = []
    for ki, k in enumerate(kernels):
        curves = {a: [float(s) for s in slow[ki, ai]]
                  for ai, a in enumerate(axes)}
        scores = {a: curves[a][-1] for a in axes}
        reports.append(SensitivityReport(k.name, curves, list(lambdas),
                                         scores))
    return reports


def sensitivity(kernel: KernelProfile, dev: DeviceModel,
                lambdas: Sequence[float] = (0.1, 0.25, 0.5, 0.75, 0.9),
                axes: Sequence[str] = RESOURCE_AXES) -> SensitivityReport:
    return sensitivity_batch([kernel], dev, lambdas, axes)[0]


def cache_pollution_curve(kernel: KernelProfile, dev: DeviceModel,
                          polluter_ws: Sequence[float]) -> List[float]:
    """Paper Fig. 3: slowdown of `kernel` vs a polluter's working set —
    the whole sweep is one batched solve."""
    polluter_ws = list(polluter_ws)
    if not polluter_ws:
        return []
    base_demand = {**{r: 0.0 for r in RESOURCE_AXES},
                   "hbm": dev.hbm_bw * 0.5, "l2": dev.l2_bw * 0.5}
    polluters = [KernelProfile("polluter", demand=base_demand,
                               cache_working_set=ws, cache_hit_fraction=1.0)
                 for ws in polluter_ws]
    br = solve_scenarios([Scenario((kernel,), (p,)) for p in polluters], dev)
    return [float(s) for s in br.slowdowns[:, 0]]
