"""k-way slot-fraction search (paper §5.3: green-context provisioning).

The paper argues that slot partitioning is the lever that turns
SLO-violating colocations into feasible ones — but *which* fractions to
grant each member is a search problem, not a lookup: iGniter-style
interference-aware provisioning needs the whole fraction vector, and a
fixed first-member grid (the legacy ``_PARTITION_FRACTIONS`` sweep)
explores a single ray of the simplex.

This module is that search:

  * ``simplex_candidates(k, steps)`` enumerates the coarse grid — every
    fraction vector ``(a_1/m, ..., a_k/m)`` with positive integer parts
    summing to ``m``, in lexicographic order.  For ``k=2, steps=4`` this
    is exactly the legacy pair grid ``f ∈ {0.25, 0.5, 0.75}`` (first
    member ascending), so a coarse-only search reproduces the seed
    planner bit-for-bit.
  * ``refinement_candidates`` is the sensitivity-guided local step:
    around the best coarse point, move a half-grid-step of slot share
    toward the member that dominates the group — the makespan owner
    (``time x slowdown`` argmax) when the point is feasible, the most
    SLO-violating member when it is not.  One candidate per donor.
  * ``search_group_fractions`` prices MANY groups at once: every
    (group × fraction-vector × member-kernel) probe is compiled into one
    deduplicated ``solve_scenarios`` pass per search phase (coarse, then
    one pass per refinement level), so the scheduler can fraction-search
    a whole arrival row of SLO-failing pairs in two or three batched
    solves.

Selection rule (shared with ``evaluate_group_partitioned`` and the
scheduler's pair pricing, and pinned bit-identical by tests): among
feasible candidates the max gain wins, earliest candidate on ties; with
no feasible candidate the least-violating one (min over candidates of
``max_i slowdown_i / slo_i``) anchors the next refinement level and is
returned with ``meets_slo=False``.  ANY feasible partition beats an
infeasible full-share placement — the legacy ``gain > 0`` comparison
discarded feasible partitions with non-positive gain.

Fraction semantics follow the estimator contract: fractions bind to
kernels BY NAME (a member kernel is restricted only when its name equals
the workload's name; the representative background kernels always are),
members at or below ``FRACTION_FLOOR`` are absent, and a group's
fractions always sum to exactly 1 (coarse vectors by construction,
refinement moves preserve the sum).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.estimator import FRACTION_FLOOR, solve_scenarios
from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import DeviceModel
from repro.core.scenario import group_victim_scenarios


@dataclass(frozen=True)
class FractionSearchConfig:
    """Knobs of the k-way fraction search.

    coarse_steps: resolution 1/m of the coarse simplex grid for pairs;
        larger groups automatically refine to ``max(m, k + 2)`` steps so
        the grid has more than the uniform point.  The default (8) is a
        strict superset of the legacy pair grid (4 -> f in {.25,.5,.75})
        and flips real SLO-violating pairs to feasible that the fixed
        grid misses (pinned by tests).
    refine_levels: sensitivity-guided local passes around the best
        coarse point; level r moves slot share in steps of 1/(m 2^r).
        0 = coarse grid only (the legacy fixed-grid behavior at k=2).
    grow_partitioned: let the scheduler grow partitioned pairs into
        partitioned k-way groups (re-searching fractions per candidate).
    """
    coarse_steps: int = 8
    refine_levels: int = 1
    grow_partitioned: bool = True

    def __post_init__(self):
        if self.coarse_steps < 2:
            raise ValueError("coarse_steps must be >= 2")
        if self.refine_levels < 0:
            raise ValueError("refine_levels must be >= 0")

    def steps_for(self, k: int) -> int:
        return max(self.coarse_steps, k + 2)

    @classmethod
    def default(cls) -> "FractionSearchConfig":
        """The search config for the ACTIVE solver backend: the standard
        8-step grid on numpy, `DENSE_SEARCH` on jax — the jitted solver
        prices candidates cheaply enough to widen the grid at unchanged
        latency budgets (ISSUE 8).  Resolved at call time, so switch the
        backend before constructing schedulers."""
        from repro.core.backend import get_solver_backend
        return DENSE_SEARCH if get_solver_backend() == "jax" else cls()


# coarse-only, no partitioned growth: bit-for-bit the seed planner's
# fixed first-member grid at k=2 (pinned by tests against the seed)
LEGACY_SEARCH = FractionSearchConfig(coarse_steps=4, refine_levels=0,
                                     grow_partitioned=False)

# jax-backend default: 16ths keep the 8-step grid AND its level-1
# refinement points (which land on 16ths) as a strict subset, so the
# dense search's selected gain can never regress the standard config's;
# the extra refine level then explores 64ths around the winner.
DENSE_SEARCH = FractionSearchConfig(coarse_steps=16, refine_levels=2)


@dataclass
class GroupFractions:
    """Best fraction assignment found for one group."""
    fractions: Tuple[float, ...]        # per member, in group order; sum == 1
    gain: float                         # packed gain at these fractions
    meets_slo: bool
    slowdowns: Dict[str, float]         # member name -> workload slowdown


def group_metrics(times: Sequence[float], slows: Sequence[float],
                  slos: Sequence[float]) -> Tuple[float, bool]:
    """THE definition of a placement's packed gain (serial time /
    colocated makespan) and SLO feasibility, for any group size.
    `evaluate_group`, the scheduler's batched group pricing, and the
    fraction search all call it; the scheduler's `_pair_metrics` is its
    vectorized two-member twin — keep them in lockstep."""
    serial = sum(times)
    makespan = max((t * r for t, r in zip(times, slows)), default=0.0)
    gain = serial / max(makespan, 1e-12)
    meets = all(r <= s for r, s in zip(slows, slos))
    return float(gain), bool(meets)


def member_slowdowns(members: Sequence[WorkloadProfile], dev: DeviceModel,
                     victim_slowdowns: np.ndarray) -> Dict[str, float]:
    """Fold per-kernel victim slowdowns (in ``group_victim_scenarios``
    order) into per-member workload slowdowns: duration-weighted mean
    over the member's kernels (0-time members -> 0.0, seed semantics)."""
    slows: Dict[str, float] = {}
    row = 0
    for w in members:
        tot_iso = tot_col = 0.0
        for k in w.kernels:
            t = k.isolated_time(dev) * k.duration_weight
            tot_iso += t
            tot_col += t * float(victim_slowdowns[row])
            row += 1
        slows[w.name] = tot_col / max(tot_iso, 1e-12)
    return slows


def simplex_candidates(k: int, steps: int) -> List[Tuple[float, ...]]:
    """All fraction vectors (a_1/steps, ..., a_k/steps) with integer
    a_i >= 1 summing to `steps`, lexicographically ascending.  C(steps-1,
    k-1) vectors; for k=2, steps=4 exactly the legacy pair grid."""
    if k < 1:
        raise ValueError("group size must be >= 1")
    if steps < k:
        raise ValueError(f"steps={steps} cannot split into {k} positive parts")
    out: List[Tuple[float, ...]] = []

    def rec(prefix: List[int], remaining: int, slots: int):
        if slots == 1:
            out.append(tuple((a / steps) for a in prefix + [remaining]))
            return
        for a in range(1, remaining - (slots - 1) + 1):
            rec(prefix + [a], remaining - a, slots - 1)

    rec([], steps, k)
    return out


def refinement_candidates(best: Sequence[float], times: Sequence[float],
                          slows: Sequence[float], slos: Sequence[float],
                          meets: bool, delta: float
                          ) -> List[Tuple[float, ...]]:
    """Sensitivity-guided neighbors of `best`: transfer `delta` of slot
    share toward the group's binding member — the makespan owner
    (argmax time x slowdown) when feasible, the worst SLO violator
    (argmax slowdown/slo) when not — from each other member in turn.
    Moves that would push a donor to (or below) the exclusion floor are
    skipped, so every candidate keeps all members present and the
    fractions summing to exactly 1."""
    k = len(best)
    if k < 2:
        return []
    load = [t * r for t, r in zip(times, slows)]
    viol = [r / max(s, 1e-12) for r, s in zip(slows, slos)]
    recv = int(np.argmax(load)) if meets else int(np.argmax(viol))
    cands: List[Tuple[float, ...]] = []
    for donor in range(k):
        if donor == recv or best[donor] - delta <= FRACTION_FLOOR:
            continue
        vec = list(best)
        vec[donor] -= delta
        vec[recv] += delta
        cands.append(tuple(vec))
    return cands


# selection state per group: (feasible?, gain, max violation, result)
_Best = Tuple[bool, float, float, GroupFractions]


def _better(cand: _Best, cur: Optional[_Best]) -> bool:
    """Strict improvement: feasible beats infeasible; among feasible,
    strictly higher gain; among infeasible, strictly lower violation.
    Strictness keeps the EARLIEST candidate on ties (the legacy grid's
    first-max rule, and what makes the search order-deterministic)."""
    if cur is None:
        return True
    if cand[0] != cur[0]:
        return cand[0]
    return (cand[1] > cur[1]) if cand[0] else (cand[2] < cur[2])


def _price_candidates(groups: Sequence[Sequence[WorkloadProfile]],
                      cands_per_group: Sequence[Sequence[Tuple[float, ...]]],
                      dev: DeviceModel,
                      reps: Mapping[str, KernelProfile],
                      stats: Optional[Dict[str, int]]
                      ) -> List[List[_Best]]:
    """One deduplicated solve over every (group x fraction-vector x
    member-kernel) probe; returns per-group, per-candidate metrics."""
    scenarios = []
    spans: List[Tuple[int, int]] = []       # (group index, candidate index)
    for gi, (group, cands) in enumerate(zip(groups, cands_per_group)):
        names = [w.name for w in group]
        for ci, vec in enumerate(cands):
            sf = dict(zip(names, vec))
            scenarios.extend(group_victim_scenarios(group, reps, sf))
            spans.append((gi, ci))
    if stats is not None:
        stats["scenarios_solved"] = (stats.get("scenarios_solved", 0)
                                     + len(scenarios))
    br = solve_scenarios(scenarios, dev)
    out: List[List[_Best]] = [[] for _ in groups]
    row = 0
    for gi, ci in spans:
        group = groups[gi]
        n_rows = sum(len(w.kernels) for w in group)
        slows = member_slowdowns(group, dev,
                                 br.slowdowns[row:row + n_rows, 0])
        row += n_rows
        times = [w.total_time(dev) for w in group]
        slos = [w.slo_slowdown for w in group]
        svec = [slows[w.name] for w in group]
        gain, meets = group_metrics(times, svec, slos)
        viol = max((r / max(s, 1e-12) for r, s in zip(svec, slos)),
                   default=0.0)
        out[gi].append((meets, gain, viol, GroupFractions(
            cands_per_group[gi][ci], gain, meets, slows)))
    return out


def search_group_fractions(groups: Sequence[Sequence[WorkloadProfile]],
                           dev: DeviceModel,
                           config: Optional[FractionSearchConfig] = None,
                           reps: Optional[Mapping[str, KernelProfile]] = None,
                           candidates: Optional[
                               Sequence[Sequence[Tuple[float, ...]]]] = None,
                           stats: Optional[Dict[str, int]] = None
                           ) -> List[GroupFractions]:
    """Best slot-fraction vector for every group, batched.

    groups: workload groups (size >= 2) to search independently.
    reps: shared name -> representative-kernel cache (recomputed when
        omitted — callers holding memoized reps pass them in).
    candidates: explicit per-group fraction vectors; when given, only
        those are priced and NO refinement runs (the legacy first-member
        grid path of ``evaluate_group_partitioned(fractions=...)``).
    stats: optional counter dict; "scenarios_solved" is incremented by
        every estimator scenario the search prices (the scheduler's
        O(n)-per-arrival accounting).

    Returns one GroupFractions per group: the feasible max-gain
    assignment, or (``meets_slo=False``) the least-SLO-violating one.
    """
    cfg = config or FractionSearchConfig.default()
    groups = [list(g) for g in groups]
    for g in groups:
        if len(g) < 2:
            raise ValueError("fraction search needs groups of >= 2 members")
    if reps is None:
        reps = {w.name: w.representative_kernel(dev)
                for g in groups for w in g}

    if candidates is not None:
        cands = [list(c) for c in candidates]
        refine = 0
    else:
        grids: Dict[int, List[Tuple[float, ...]]] = {}
        cands = []
        for g in groups:
            k = len(g)
            if k not in grids:
                grids[k] = simplex_candidates(k, cfg.steps_for(k))
            cands.append(grids[k])
        refine = cfg.refine_levels

    best: List[Optional[_Best]] = [None] * len(groups)
    priced = _price_candidates(groups, cands, dev, reps, stats)
    for gi, results in enumerate(priced):
        for cand in results:
            if _better(cand, best[gi]):
                best[gi] = cand
    for gi in range(len(groups)):
        if best[gi] is None:        # empty candidate list: nothing priced
            best[gi] = (False, float("-inf"), float("inf"),
                        GroupFractions((), float("-inf"), False, {}))

    for level in range(1, refine + 1):
        refine_cands: List[List[Tuple[float, ...]]] = []
        for gi, g in enumerate(groups):
            meets, _, _, res = best[gi]
            if not res.fractions:
                refine_cands.append([])
                continue
            delta = 1.0 / (cfg.steps_for(len(g)) * (2 ** level))
            refine_cands.append(refinement_candidates(
                res.fractions, [w.total_time(dev) for w in g],
                [res.slowdowns[w.name] for w in g],
                [w.slo_slowdown for w in g], meets, delta))
        if not any(refine_cands):
            break
        priced = _price_candidates(groups, refine_cands, dev, reps, stats)
        for gi, results in enumerate(priced):
            for cand in results:
                if _better(cand, best[gi]):
                    best[gi] = cand

    return [b[3] for b in best]
