"""JAX-jitted port of the batched water-filling interference solver.

This is the accelerator-resident twin of the NumPy solver in
`repro.core.estimator` (ROADMAP item 2): the effective-demand /
cache-share precompute, the freeze-round water-filling fixed point
(``lax.while_loop`` over the fixed ``K + N_AXES`` bound with the per-
scenario ``done`` mask, including the smem equal-throttle branch and the
sorted-cumsum theta computation), and the queueing-inflation epilogue —
written as pure padded-array functions over ONE scenario and ``vmap``ped
over the batch, so XLA fuses the whole pricing pipeline into a handful
of kernels on whatever backend jax runs on (CPU today, TPU/GPU when
present).

Numerical contract: float64 everywhere (x64 is force-enabled at import;
the parity gate is meaningless in f32), every floor/tolerance constant
imported from `repro.core.estimator` (never re-typed here), and results
equal to the NumPy oracle at 1e-9 — enforced by
``tests/test_estimator_jax.py`` and the ``bench_planner`` solver gate in
CI.  Selection happens in `repro.core.backend`; this module is only
imported when the jax backend is requested.

Shape discipline: one trace per padded (S, K) shape.  Batch sizes are
bucketed up to powers of two (scenario padding rows are fully masked and
solve to no-ops), so a scheduler churning through thousands of distinct
batch sizes compiles O(log S_max x distinct K) programs, not O(events).

The cache-share / thrash-cliff stage optionally runs as a Pallas TPU
kernel (`repro.kernels.cache_share`) when jax is actually executing on a
TPU; everywhere else the jnp fallback computes the identical expression
(platform detection at dispatch, never inside the trace).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import numpy as np

import jax

# the 1e-9 parity contract requires double precision — force it before
# any array is created (harmless if already enabled via JAX_ENABLE_X64)
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 flip, by design)
from jax import lax  # noqa: E402

from repro.core.estimator import (CAP_REMAIN_FLOOR, DEMAND_EPS,  # noqa: E402
                                  FRACTION_FLOOR, OVERSUB_RTOL, RATIO_FLOOR,
                                  SPEED_FLOOR, TIME_EPS, _INFLATION,
                                  _INFLATION_MAJORITY, _INFLATION_MIN_UTIL,
                                  _N_AXES, _SMEM, PER_SLOT_AXES)
from repro.core.resources import AXIS_INDEX, RESOURCE_AXES, DeviceModel  # noqa: E402

_HBM = AXIS_INDEX["hbm"]
_L2 = AXIS_INDEX["l2"]
_PER_SLOT_MASK = np.array([r in PER_SLOT_AXES for r in RESOURCE_AXES])

# batch-size bucket floor: tiny scheduler batches all share one trace
_MIN_BUCKET = 8

# incremented inside the traced function — counts actual XLA traces, so
# tests can pin the jit cache behavior (same bucket twice -> one trace)
_trace_count = 0


def trace_count() -> int:
    return _trace_count


def _bucket(s: int) -> int:
    """Next power of two >= s (floored at _MIN_BUCKET): the padded batch
    size a solve of s scenarios compiles for."""
    b = _MIN_BUCKET
    while b < s:
        b <<= 1
    return b


# --------------------------------------------------------------------- #
#  Per-scenario solve (vmapped over the batch)                           #
# --------------------------------------------------------------------- #
def _effective_demand(demand, ws, hit, cache_cap, share):
    """jnp twin of profile.effective_demand_arrays (cache hits discount
    HBM traffic; the absorbed stream reappears as L2 demand)."""
    cached = (ws > 0) & (hit > 0)
    resident = jnp.minimum(1.0, (cache_cap * share) / jnp.maximum(ws, 1.0))
    hit_f = hit * resident
    d_hbm = jnp.where(cached, demand[..., _HBM] * (1.0 - hit_f),
                      demand[..., _HBM])
    d_l2 = jnp.where(cached,
                     jnp.maximum(demand[..., _L2], demand[..., _HBM]),
                     demand[..., _L2])
    d = demand.at[..., _HBM].set(d_hbm)
    return d.at[..., _L2].set(d_l2)


def cache_share_ref(ws, present, cache_cap):
    """The cache-share / thrash-cliff stage (jnp reference used on
    non-TPU platforms and as the Pallas kernel's oracle): isolated
    residency is proportional (min(1, C/ws)); colocated streaming
    residency collapses once the combined working set exceeds capacity
    (paper Fig. 3's thrash cliff).  ws must already be exclusion-zeroed;
    shapes (S, K) / scalar -> (S, K)."""
    total_ws = ws.sum(-1, keepdims=True)
    resident_col = jnp.where(total_ws > cache_cap, 0.0, 1.0)
    nk = present.sum(-1, keepdims=True)
    has_ws = ws > 0
    return jnp.where(
        has_ws & (nk > 1), resident_col,
        jnp.where(has_ws, jnp.minimum(1.0, cache_cap / jnp.maximum(ws, 1.0)),
                  1.0))


def _solve_one(demand, duration, ws, hit, slots, frac, present, excluded,
               share, cap_vec, cache_cap, n_slots):
    """Water-fill ONE padded scenario: demand (K, A), the rest (K,).
    Inputs are already exclusion-zeroed; `share` is the precomputed
    cache share (the one batch-level stage, see _solve_padded)."""
    K = duration.shape[0]

    eff_col = _effective_demand(demand, ws, hit, cache_cap, share)
    t_col = jnp.maximum((eff_col / cap_vec).max(-1), duration)
    eff_iso = _effective_demand(demand, ws, hit, cache_cap,
                                jnp.ones_like(share))
    t_iso = jnp.maximum((eff_iso / cap_vec).max(-1), duration)
    u = jnp.where(t_col[:, None] > 0,
                  (eff_col / t_col[:, None]) / cap_vec, 0.0)
    slot_scale = jnp.where(frac < 1.0, jnp.maximum(frac, FRACTION_FLOOR),
                           1.0)
    u = jnp.where(_PER_SLOT_MASK[None, :], u / slot_scale[:, None], u)
    axis_load = u.sum(0)

    # freeze-round fixed point: while any axis is oversubscribed, freeze
    # its over-fair-share users (equal throttle on smem, max-min theta
    # elsewhere).  The K + N_AXES bound and the `done` mask mirror the
    # NumPy loop exactly; under vmap, finished scenarios' carries are
    # masked while stragglers keep iterating.
    def cond(carry):
        i, _, _, _, _, done = carry
        return (~done) & (i < K + _N_AXES)

    def body(carry):
        i, speeds, active, frozen, used, done = carry
        dem = (u * (speeds * active)[:, None]).sum(0)
        cap_rem = jnp.maximum(1.0 - used, CAP_REMAIN_FLOOR)
        ratio = dem / cap_rem
        worst = jnp.argmax(ratio)
        worst_ratio = ratio[worst]
        done = done | (worst_ratio <= 1.0 + OVERSUB_RTOL)
        live = ~done
        d = speeds * u[:, worst]

        # smem: bank-conflict serialization throttles EVERY user equally
        is_smem = live & (worst == _SMEM)
        users = active & (d > DEMAND_EPS) & is_smem
        s_eq = 1.0 / jnp.maximum(worst_ratio, RATIO_FLOOR)
        speeds = jnp.where(users, speeds * s_eq, speeds)
        used = used + (u * (speeds * users)[:, None]).sum(0)
        frozen = jnp.where(users, _SMEM, frozen)
        active = active & ~users

        # max-min rate cap theta on worst: sum min(d_n, theta) = cap.
        is_mm = live & (worst != _SMEM)
        elig = active & (d > DEMAND_EPS) & is_mm
        cap_w = cap_rem[worst]
        ds = jnp.where(elig, d, jnp.inf)
        order = jnp.sort(ds)
        finite = jnp.isfinite(order)
        vals = jnp.where(finite, order, 0.0)
        csum = jnp.cumsum(vals)
        m = elig.sum()
        pos = jnp.arange(K)
        even = (cap_w - (csum - vals)) / jnp.maximum(m - pos, 1)
        breach = finite & (order > even) & (pos < m)
        has_theta = breach.any() & is_mm
        theta = even[jnp.argmax(breach)]
        # no breach -> every user fits under the fair share: done
        done = done | (is_mm & ~has_theta)
        throttled = elig & has_theta & (d > theta)
        speeds = jnp.where(throttled,
                           speeds * (theta / jnp.where(d > 0, d, 1.0)),
                           speeds)
        used = used + (u * (speeds * throttled)[:, None]).sum(0)
        frozen = jnp.where(throttled, worst, frozen)
        active = active & ~throttled
        return (i + 1, speeds, active, frozen, used, done)

    init = (jnp.int64(0), jnp.ones(K), present,
            jnp.full(K, -1, jnp.int64), jnp.zeros(_N_AXES),
            jnp.asarray(False))
    _, speeds, _, frozen, _, _ = lax.while_loop(cond, body, init)

    # queueing inflation on near-saturated latency-sensitive axes
    base = (t_col / jnp.maximum(t_iso, TIME_EPS)) / jnp.maximum(speeds,
                                                                SPEED_FLOOR)
    infl = jnp.ones(K)
    for axis, (gamma, p) in _INFLATION.items():
        ai = AXIS_INDEX[axis]
        u_ax = u[:, ai]
        rho = jnp.minimum(1.0, (speeds * u_ax).sum())
        skip = ((frozen == ai) | (u_ax <= _INFLATION_MIN_UTIL)
                | (u_ax >= _INFLATION_MAJORITY
                   * jnp.maximum(rho, SPEED_FLOOR)))
        infl = infl + jnp.where(~skip & present, gamma * rho ** p, 0.0)
    slowdowns = base * infl
    speeds = jnp.where(excluded, 0.0, speeds)
    slowdowns = jnp.where(excluded, jnp.inf, slowdowns)

    tot_slots = (slots * jnp.minimum(frac, 1.0)).sum()
    feasible = (tot_slots <= n_slots) | (tot_slots == 0)
    return speeds, slowdowns, frozen, axis_load, feasible


@partial(jax.jit, static_argnames=("use_pallas_share",))
def _solve_padded(demand, duration, ws, hit, slots, frac, mask, cap_vec,
                  cache_cap, n_slots, *, use_pallas_share: bool = False):
    """The whole batch solve as one XLA program: exclusion zeroing, the
    cache-share stage (Pallas on TPU), then the vmapped per-scenario
    water-fill.  One trace per (padded S, K, use_pallas_share)."""
    global _trace_count
    _trace_count += 1
    excluded = mask & (frac <= FRACTION_FLOOR)
    present = mask & ~excluded
    demand = jnp.where(present[:, :, None], demand, 0.0)
    duration = jnp.where(present, duration, 0.0)
    ws = jnp.where(present, ws, 0.0)
    hit = jnp.where(present, hit, 0.0)
    slots = jnp.where(present, slots, 0.0)
    if use_pallas_share:
        from repro.kernels.cache_share import cache_share_pallas
        share = cache_share_pallas(ws, present, cache_cap)
    else:
        share = cache_share_ref(ws, present, cache_cap)
    return jax.vmap(
        _solve_one,
        in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, None, None, None))(
        demand, duration, ws, hit, slots, frac, present, excluded, share,
        cap_vec, cache_cap, n_slots)


def warmup(dev: DeviceModel, ks=(2, 3),
           buckets=(_MIN_BUCKET,)) -> int:
    """Ahead-of-time compile the (bucket, K) shapes a scheduler's group
    pricing will hit, with all-masked zero batches (they solve to
    no-ops).  The dummy operands match the real call signature exactly —
    float64 numpy arrays, python-float scalars — so the warmed traces
    ARE the cache entries later solves hit; device capacities are traced
    operands, so the traces are shared across device models.  Returns
    the number of new traces compiled (0 when every shape was warm)."""
    before = _trace_count
    use_pallas = _use_pallas_share()
    for K in ks:
        for S in buckets:
            shape = (int(S), int(K))
            _solve_padded(
                np.zeros(shape + (_N_AXES,)), np.zeros(shape),
                np.zeros(shape), np.zeros(shape), np.zeros(shape),
                np.ones(shape), np.zeros(shape, bool),
                dev.capacity_vector(), dev.cache_capacity,
                float(dev.n_slots), use_pallas_share=use_pallas)
    return _trace_count - before


def _use_pallas_share() -> bool:
    """Platform detection for the Pallas cache-share kernel: only when
    jax is actually executing on a TPU (the lax fallback is the same
    expression everywhere else — CPU CI, GPU)."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:          # pragma: no cover - backend probing failed
        return False


def solve_gathered(mask, frac, demand, duration, ws, hit, slots,
                   dev: DeviceModel) -> Tuple[np.ndarray, ...]:
    """Entry point for `estimator.solve_batch`'s jax dispatch: takes the
    NumPy-gathered padded arrays, pads the batch up to its size bucket
    (masked rows solve to no-ops), runs the jitted program, and returns
    NumPy (speeds, slowdowns, bottleneck, axis_load, feasible_slots)."""
    S, K = mask.shape
    pad = _bucket(S) - S
    if pad:
        z = ((0, pad), (0, 0))
        mask = np.pad(mask, z)
        frac = np.pad(frac, z, constant_values=1.0)
        demand = np.pad(demand, z + ((0, 0),))
        duration = np.pad(duration, z)
        ws = np.pad(ws, z)
        hit = np.pad(hit, z)
        slots = np.pad(slots, z)
    out = _solve_padded(demand, duration, ws, hit, slots, frac, mask,
                        dev.capacity_vector(), dev.cache_capacity,
                        float(dev.n_slots),
                        use_pallas_share=_use_pallas_share())
    speeds, slowdowns, frozen, axis_load, feasible = (
        np.asarray(o)[:S] for o in out)
    return speeds, slowdowns, frozen, axis_load, feasible
