"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, the three terms in seconds:
  compute   = HLO_mxu_FLOPs_per_chip / peak_FLOP/s
  memory    = HLO_bytes_per_chip / HBM_bw
  collective= collective_bytes_per_chip / (links x link_bw)

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (decode)
and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from repro.core.resources import TPU_V5E, DeviceModel

# 16x16 torus: each chip has 4 ICI links; bidirectional rings give ~3
# usable links of effective bandwidth for typical collectives — we report
# conservatively with 1.5 effective links (mixed all-reduce/all-gather).
EFFECTIVE_LINKS = 1.5


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    kind: str
    recipe: str
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float            # global
    useful_ratio: float
    bound: str
    roofline_frac: float        # model-flops-time / bound-time
    fits_hbm: bool
    hbm_gb: float

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | {self.recipe} "
                f"| {self.compute_s * 1e3:.1f} | {self.memory_s * 1e3:.1f} "
                f"| {self.collective_s * 1e3:.1f} | {self.bound} "
                f"| {self.useful_ratio:.2f} | {self.roofline_frac:.3f} "
                f"| {self.hbm_gb:.1f} |")


def model_flops_of(rec: dict) -> float:
    n_tok_map = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32}
    shape, kind = rec["shape"], rec["kind"]
    n = rec["n_params"]
    n_act = rec["n_active_params"]
    if kind == "train":
        return 6.0 * n_act * n_tok_map[shape]
    if kind == "prefill":
        return 2.0 * n_act * n_tok_map[shape]
    # decode: one token per sequence
    batch = {"decode_32k": 128, "long_500k": 1}[shape]
    return 2.0 * n_act * batch


def analyze_record(rec: dict, dev: DeviceModel = TPU_V5E) -> RooflineRow:
    n = rec["n_chips"]
    h = rec["hlo_exec"]
    compute = h["mxu_flops"] / dev.mxu_flops
    memory = h["hbm_bytes"] / dev.hbm_bw
    coll = rec["collectives"]["total_bytes"] / (dev.ici_bw * EFFECTIVE_LINKS)
    mf = model_flops_of(rec)
    hlo_total = h["mxu_flops"] * n
    bound_s = max(compute, memory, coll, 1e-12)
    bound = {compute: "compute", memory: "memory", coll: "collective"}[
        max(compute, memory, coll)]
    ideal = mf / n / dev.mxu_flops
    mem = rec["memory"]
    # outputs aliased to donated inputs (decode cache) are not extra HBM
    hbm_gb = (mem["argument_bytes"] + mem["temp_bytes"]
              + mem["output_bytes"] - mem.get("alias_bytes", 0)) / 1e9
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"],
        mesh="2x16x16" if rec.get("multi_pod") else "16x16",
        kind=rec["kind"], recipe=rec.get("recipe", "?"), n_chips=n,
        compute_s=compute, memory_s=memory, collective_s=coll,
        model_flops=mf, hlo_flops=hlo_total,
        useful_ratio=mf / max(hlo_total, 1e-9),
        bound=bound, roofline_frac=ideal / bound_s,
        fits_hbm=hbm_gb <= dev.hbm_capacity / 1e9, hbm_gb=hbm_gb)


def load_results(results_dir: str = "results/dryrun",
                 tag: str = "") -> List[RooflineRow]:
    rows = []
    for f in sorted(Path(results_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            continue
        if tag != rec.get("tag", ""):
            continue
        rows.append(analyze_record(rec))
    return rows


HEADER = ("| arch | shape | mesh | recipe | compute ms | memory ms "
          "| collective ms | bound | useful | roofline | HBM GB/chip |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def table(rows: List[RooflineRow]) -> str:
    return "\n".join([HEADER] + [r.row() for r in rows])
