"""Scenario — the one colocation-query currency of the estimator stack.

Every consumer of the interference estimator asks the same question:
"how much do these VICTIM kernels slow down when colocated with this
BACKGROUND, under these slot fractions, on this device?"  Before this
module each consumer spelled the question differently — the planner
built raw (row, row) index arrays, sensitivity built [[ki, si]] member
lists, the serve engine built its own ProfileMatrix and never asked the
solver at all.  ``Scenario`` is the shared spelling; ``compile_scenarios``
lowers a batch of them to the dense ProfileMatrix + member-index form the
vectorized solver consumes (`repro.core.estimator.solve_scenarios`).

Conventions
  * members are ordered victims-first: row ``s`` of the solved batch has
    the victim slowdowns in ``slowdowns[s, :n_victims[s]]``;
  * ``slot_fraction`` is keyed by KERNEL NAME (the ``estimate()``
    contract): a member picks up a fraction iff its name is a key;
  * kernels are deduplicated by object identity, so a background kernel
    shared across thousands of scenarios occupies one matrix row.

Hot paths that already hold dense index arrays (the scheduler's pairwise
row pricing) skip the per-scenario Python objects and hand `solve_batch`
the arrays directly — Scenario is the currency, not a toll booth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.profile import KernelProfile, ProfileMatrix, WorkloadProfile
from repro.core.resources import DeviceModel


@dataclass(frozen=True)
class Scenario:
    """One colocation query: victims + background + fractions (+ device).

    ``victims`` are the kernels whose slowdowns the caller reads back;
    ``background`` kernels contend but their slowdowns are incidental.
    The split is bookkeeping for the caller — the fluid solver treats
    all members identically.
    """
    victims: Tuple[KernelProfile, ...]
    background: Tuple[KernelProfile, ...] = ()
    slot_fraction: Optional[Mapping[str, float]] = None
    device: Optional[DeviceModel] = None

    @property
    def members(self) -> Tuple[KernelProfile, ...]:
        return tuple(self.victims) + tuple(self.background)

    @property
    def n_victims(self) -> int:
        return len(self.victims)

    def fraction_of(self, kernel: KernelProfile) -> float:
        if not self.slot_fraction:
            return 1.0
        return float(self.slot_fraction.get(kernel.name, 1.0))


@dataclass
class CompiledScenarios:
    """Scenario batch lowered to solver inputs (see estimator.solve_batch).

    ``members`` is always a dense (S, K_max) int64 ndarray.  Uniform-width
    batches (the common fan-out shape) carry ``mask=None``; ragged batches
    are padded to the widest scenario with ``mask`` marking real members,
    so mixed k-way batches still hit one dense solve on both backends.
    """
    pm: ProfileMatrix
    members: Union[np.ndarray, List[List[int]]]
    fractions: Optional[Union[np.ndarray, List[List[float]]]]
    n_victims: np.ndarray                 # (S,)
    mask: Optional[np.ndarray] = None     # (S, K_max) bool, None if uniform

    def __len__(self) -> int:
        return len(self.n_victims)


def compile_scenarios(scenarios: Sequence[Scenario]) -> CompiledScenarios:
    """Lower Scenario objects to one ProfileMatrix + member index lists,
    deduplicating kernels by identity across the whole batch."""
    row_of: Dict[int, int] = {}
    profiles: List[KernelProfile] = []

    def row(k: KernelProfile) -> int:
        r = row_of.get(id(k))
        if r is None:
            r = row_of[id(k)] = len(profiles)
            profiles.append(k)
        return r

    members: List[List[int]] = []
    fractions: List[List[float]] = []
    n_victims = np.empty(len(scenarios), np.int64)
    any_fraction = False
    for s, sc in enumerate(scenarios):
        ms = sc.members
        members.append([row(k) for k in ms])
        fractions.append([sc.fraction_of(k) for k in ms])
        any_fraction = any_fraction or bool(sc.slot_fraction)
        n_victims[s] = sc.n_victims

    pm = ProfileMatrix.from_profiles(profiles)
    widths = {len(m) for m in members}
    if len(widths) == 1 and widths != {0}:
        dense = np.asarray(members, np.int64)
        frac = np.asarray(fractions, np.float64) if any_fraction else None
        return CompiledScenarios(pm, dense, frac, n_victims)
    # Ragged (or all-empty) batch: pad to the widest scenario and carry a
    # member mask so the solver still sees ONE dense batch.  Padded slots
    # index row 0 with fraction 1.0 but are masked out of every reduction.
    S = len(members)
    K = max((len(m) for m in members), default=0)
    idx = np.zeros((S, K), np.int64)
    mask = np.zeros((S, K), bool)
    frac = np.ones((S, K), np.float64)
    for s, m in enumerate(members):
        idx[s, :len(m)] = m
        mask[s, :len(m)] = True
        frac[s, :len(m)] = fractions[s]
    return CompiledScenarios(pm, idx, frac if any_fraction else None,
                             n_victims, mask)


def group_victim_scenarios(members: Sequence[WorkloadProfile],
                           reps: Mapping[str, KernelProfile],
                           slot_fraction: Optional[Mapping[str, float]] = None,
                           device: Optional[DeviceModel] = None
                           ) -> List[Scenario]:
    """THE group-pricing probe set, shared by ``evaluate_group``, the
    scheduler's batched group pricing, and the k-way fraction search:
    one Scenario per member kernel — victim = that kernel, background =
    every OTHER member's representative kernel (``reps``, keyed by
    member name).

    Row order of the solved batch is members in the given order, each
    member's kernels in profile order (fold back per workload with
    ``repro.core.fracsearch.member_slowdowns``).  Slot fractions follow
    the estimator contract — they bind by KERNEL name, so a fraction
    keyed by a workload's name restricts its representative (background)
    kernel everywhere, and its victim kernels only when they share the
    workload's name.
    """
    out: List[Scenario] = []
    for m in members:
        bg = tuple(reps[o.name] for o in members if o is not m)
        for k in m.kernels:
            out.append(Scenario((k,), bg, slot_fraction, device))
    return out


def scenario_device(scenarios: Sequence[Scenario],
                    dev: Optional[DeviceModel] = None) -> DeviceModel:
    """Resolve the one device a scenario batch runs on: an explicit `dev`
    wins; otherwise every scenario must name the same device."""
    if dev is not None:
        return dev
    devs = {sc.device for sc in scenarios if sc.device is not None}
    if len(devs) != 1:
        raise ValueError(
            "scenario batch needs one device: pass dev= or set the same "
            f"Scenario.device on every scenario (got {len(devs)})")
    return next(iter(devs))
