"""Interference-aware colocation planner (paper §5.1).

Given workload profiles with SLOs, the planner:
  1. builds the pairwise predicted-slowdown matrix with ONE batched
     estimator solve (per-kernel granularity -> workload-level
     aggregation) — O(n^2) estimator work total,
  2. greedily pairs workloads to maximize packed throughput subject to
     every member staying within its SLO slowdown; the greedy rounds run
     over a max-heap of the precomputed pairs with lazy invalidation
     (each placement just marks its two members used; stale heap entries
     are discarded on pop), so no pair is ever re-estimated,
  3. optionally allocates slot partitions (the green-context analogue:
     disjoint chip/core fractions) when full-device sharing violates an
     SLO but partitioned sharing does not — trading marginal per-workload
     performance for colocation opportunity (paper §5.3).

The seed implementation re-evaluated every remaining pair from scratch on
each greedy round — O(n^3) estimator solves. A pair's predicted slowdown
is independent of which other workloads remain, so the pairwise matrix is
computed once up front and never changes; the heap replays the exact
greedy order (gain desc, then first pair in index order) at O(n^2 log n).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.estimator import solve_batch, workload_slowdown
from repro.core.profile import KernelProfile, ProfileMatrix, WorkloadProfile
from repro.core.resources import DeviceModel

_PARTITION_FRACTIONS = (0.25, 0.5, 0.75)
_PAIR_BLOCK = 16384          # pairs per batched solve: bounds peak memory


@dataclass
class Placement:
    workloads: List[str]
    slot_fraction: Dict[str, float]
    predicted_slowdown: Dict[str, float]
    meets_slo: bool
    throughput_gain: float       # vs running members serially

    def __repr__(self):
        mems = " + ".join(self.workloads)
        slow = ", ".join(f"{k}:{v:.2f}x" for k, v in self.predicted_slowdown.items())
        return (f"<Placement [{mems}] slow=({slow}) "
                f"gain={self.throughput_gain:.2f} slo_ok={self.meets_slo}>")


def _rep_kernel(w: WorkloadProfile, dev: DeviceModel) -> KernelProfile:
    """Time-weighted aggregate kernel used for quick pair screening."""
    u = w.mixed_utilization(dev)
    t = w.total_time(dev)
    return KernelProfile(w.name, demand={
        r: u[r] * dev.capacity(r) * t for r in u})


def _pair_metrics(ta, tb, ra, rb, slo_a, slo_b):
    """Workload-level pair aggregation — the ONE definition of packed
    gain (serial time / colocated makespan) and SLO feasibility, shared
    by the scalar evaluate_pair path and _PairEvaluator's array path
    (both call it; tweak it here and both stay in lockstep)."""
    gain = (ta + tb) / np.maximum(np.maximum(ta * ra, tb * rb), 1e-12)
    meets = (ra <= slo_a) & (rb <= slo_b)
    return gain, meets


def evaluate_pair(a: WorkloadProfile, b: WorkloadProfile, dev: DeviceModel,
                  slot_fraction: Optional[Dict[str, float]] = None
                  ) -> Placement:
    ra = workload_slowdown(a, [_rep_kernel(b, dev)], dev, slot_fraction)
    rb = workload_slowdown(b, [_rep_kernel(a, dev)], dev, slot_fraction)
    ta, tb = a.total_time(dev), b.total_time(dev)
    gain, meets = _pair_metrics(ta, tb, ra, rb,
                                a.slo_slowdown, b.slo_slowdown)
    return Placement([a.name, b.name], slot_fraction or {},
                     {a.name: ra, b.name: rb}, bool(meets), float(gain))


def evaluate_pair_partitioned(a: WorkloadProfile, b: WorkloadProfile,
                              dev: DeviceModel,
                              fractions: Sequence[float] = _PARTITION_FRACTIONS
                              ) -> Placement:
    """Try full sharing first, then slot partitions (green contexts)."""
    best = evaluate_pair(a, b, dev)
    if best.meets_slo:
        return best
    for f in fractions:
        cand = evaluate_pair(a, b, dev, {a.name: f, b.name: 1.0 - f})
        if cand.meets_slo and cand.throughput_gain > (best.throughput_gain
                                                      if best.meets_slo else 0):
            best = cand
    return best


class _PairEvaluator:
    """Batched pair evaluation over a fixed workload set.

    Compiles every workload kernel + representative background kernel into
    one ProfileMatrix and flat per-kernel arrays, so evaluating a block of
    pairs is pure array arithmetic: scenario (kernel_row, rep_row) index
    pairs come from a ragged gather over kernel counts, one `solve_batch`
    call prices them all, and workload-level slowdowns aggregate back with
    a segmented sum. No per-pair Python estimator work remains."""

    def __init__(self, works: Sequence[WorkloadProfile], dev: DeviceModel):
        self.works = list(works)
        self.dev = dev
        n = len(self.works)
        profiles: List[KernelProfile] = []
        counts, weights = [], []
        for w in self.works:
            counts.append(len(w.kernels))
            for k in w.kernels:
                profiles.append(k)
                weights.append(k.isolated_time(dev) * k.duration_weight)
        self.counts = np.asarray(counts, np.int64)
        self.offsets = np.concatenate(([0], np.cumsum(self.counts[:-1])))
        self.kernel_weight = np.asarray(weights, np.float64)
        self.rep_rows = np.arange(n, dtype=np.int64) + len(profiles)
        for w in self.works:
            profiles.append(_rep_kernel(w, dev))
        self.pm = ProfileMatrix.from_profiles(profiles)
        self.totals = np.asarray([w.total_time(dev) for w in self.works])
        self.slos = np.asarray([w.slo_slowdown for w in self.works])
        # slot-fraction dicts are keyed by KERNEL name (estimate()'s
        # contract): a member kernel only picks up a workload's fraction
        # if its name coincides with that workload's name — matching the
        # seed's evaluate_pair semantics exactly
        name_to_w = {w.name: wi for wi, w in enumerate(self.works)}
        self.kernel_name_w = np.asarray(
            [name_to_w.get(k.name, -1)
             for w in self.works for k in w.kernels], np.int64)

    def evaluate(self, ia: np.ndarray, ib: np.ndarray,
                 frac: Optional[float] = None):
        """Slowdowns/gain/SLO arrays for pairs (ia[p], ib[p]); `frac`
        gives workload ia a slot fraction of `frac` and ib the complement
        (None = full sharing), matching evaluate_pair's convention."""
        P = len(ia)
        ra = np.empty(P)
        rb = np.empty(P)
        for lo in range(0, P, _PAIR_BLOCK):
            hi = min(lo + _PAIR_BLOCK, P)
            ra[lo:hi], rb[lo:hi] = self._block(ia[lo:hi], ib[lo:hi], frac)
        gain, meets = _pair_metrics(self.totals[ia], self.totals[ib], ra, rb,
                                    self.slos[ia], self.slos[ib])
        return ra, rb, gain, meets

    def _probe_side(self, probed, other, frac_probed, frac_other):
        """Scenarios probing `probed`'s kernels against `other`'s rep."""
        cnt = self.counts[probed]
        owner = np.repeat(np.arange(len(probed)), cnt)
        start = np.repeat(np.cumsum(cnt) - cnt, cnt)
        krow = np.repeat(self.offsets[probed], cnt) \
            + np.arange(cnt.sum()) - start
        members = np.stack([krow, np.repeat(self.rep_rows[other], cnt)], 1)
        if frac_probed is None:
            fr = None
        else:
            # the probed kernel matches the sf dict only by name identity
            kw = self.kernel_name_w[krow]
            f0 = np.where(kw == np.repeat(probed, cnt), frac_probed,
                          np.where(kw == np.repeat(other, cnt), frac_other,
                                   1.0))
            fr = np.stack([f0, np.full(len(krow), frac_other)], 1)
        return members, fr, owner, self.kernel_weight[krow]

    def _block(self, ia, ib, frac):
        m_a, f_a, own_a, w_a = self._probe_side(
            ia, ib, frac, None if frac is None else 1.0 - frac)
        m_b, f_b, own_b, w_b = self._probe_side(
            ib, ia, None if frac is None else 1.0 - frac, frac)
        members = np.concatenate([m_a, m_b])
        fractions = None if frac is None else np.concatenate([f_a, f_b])
        br = solve_batch(self.pm, members, self.dev, fractions)
        slow = br.slowdowns[:, 0] * np.concatenate([w_a, w_b])
        P = len(ia)
        na, nb = len(m_a), len(m_b)
        ra = np.bincount(own_a, slow[:na], minlength=P) \
            / np.maximum(self.totals[ia], 1e-12)
        rb = np.bincount(own_b, slow[na:na + nb], minlength=P) \
            / np.maximum(self.totals[ib], 1e-12)
        return ra, rb

    def placement(self, i: int, j: int, ra: float, rb: float, gain: float,
                  meets: bool, frac: Optional[float]) -> Placement:
        a, b = self.works[i], self.works[j]
        sf = {} if frac is None else {a.name: frac, b.name: 1.0 - frac}
        return Placement([a.name, b.name], sf,
                         {a.name: float(ra), b.name: float(rb)},
                         bool(meets), float(gain))


@dataclass
class Plan:
    placements: List[Placement]
    solo: List[str]

    @property
    def total_gain(self) -> float:
        """Mean packed-throughput gain per occupied device: each placement
        contributes its members' predicted gain (serial time / colocated
        makespan), each solo workload contributes 1.0."""
        devices = len(self.placements) + len(self.solo)
        if devices == 0:
            return 1.0
        gains = sum(p.throughput_gain for p in self.placements)
        return (gains + len(self.solo)) / devices


def plan_colocation(workloads: Sequence[WorkloadProfile], dev: DeviceModel,
                    allow_partition: bool = True) -> Plan:
    """Greedy max-gain SLO-feasible pairing, O(n^2) estimator work."""
    uniq = {w.name: w for w in workloads}        # last-wins, like the seed
    works = list(uniq.values())
    names = [w.name for w in works]
    n = len(works)
    if n < 2:
        return Plan([], sorted(names))

    ev = _PairEvaluator(works, dev)
    iu, ju = np.triu_indices(n, k=1)             # pairs in (i, j) lex order
    ra, rb, gain, meets = ev.evaluate(iu, ju)    # full-sharing pass
    frac = np.full(len(iu), np.nan)              # nan = full sharing

    if allow_partition:
        # green-context fallback for SLO-violating pairs: same selection
        # rule as evaluate_pair_partitioned, batched per fraction
        failing = np.flatnonzero(~meets)
        if failing.size:
            fia, fib = iu[failing], ju[failing]
            best_gain = np.zeros(failing.size)   # full share failed -> 0
            for f in _PARTITION_FRACTIONS:
                cra, crb, cgain, cmeets = ev.evaluate(fia, fib, frac=f)
                take = cmeets & (cgain > best_gain)
                best_gain = np.where(take, cgain, best_gain)
                sel = failing[take]
                ra[sel], rb[sel] = cra[take], crb[take]
                gain[sel], meets[sel] = cgain[take], True
                frac[sel] = f

    # greedy rounds over the precomputed matrix: max-heap keyed by
    # (gain desc, pair index asc) replays the seed's exact pick order;
    # placements invalidate their members' rows lazily (skip on pop)
    feas = np.flatnonzero(meets)
    heap = list(zip(-gain[feas], iu[feas], ju[feas], feas))
    heapq.heapify(heap)
    placed = np.zeros(n, bool)
    placements: List[Placement] = []
    while heap:
        neg_gain, i, j, p = heapq.heappop(heap)
        if placed[i] or placed[j]:
            continue
        if -neg_gain <= 1.0:
            break
        f = frac[p]
        placements.append(ev.placement(
            int(i), int(j), ra[p], rb[p], gain[p], True,
            None if np.isnan(f) else float(f)))
        placed[i] = placed[j] = True
    solo = sorted(names[i] for i in np.flatnonzero(~placed))
    return Plan(placements, solo)
