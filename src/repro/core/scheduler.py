"""Interference-aware colocation scheduling (paper §5.1), online.

The public API is the stateful ``ColocationScheduler``: workloads
``submit()`` and ``remove()`` as they arrive and leave, and ``plan()``
returns the current SLO-feasible placement set.  The scheduler is
*incremental* — it keeps the pairwise price matrix (and k-way group
prices) cached across events, so

  * an arrival prices only the NEW workload's row — O(n) estimator
    scenarios, not a full O(n^2) re-price;
  * a departure never re-prices a pair: its rows are dropped and its
    group's survivors fall back into the pool with their cached prices
    (with ``max_group_size > 2`` the replay may price never-seen group
    combinations — cached from then on; at k=2 a departure solves
    exactly zero estimator scenarios);
  * ``plan()`` replays the greedy selection over the cached matrix —
    pure array/heap work, no estimator solves for already-priced pairs —
    so an online trace always lands on exactly the placements a cold
    scheduler over the surviving set would produce.

Placements are **k-way** (``max_group_size``): the greedy rounds still
seed groups from the best feasible pair (gain desc, index-order
tie-break — the seed pairing order, bit-for-bit), then grow each group
one member at a time while the packed gain improves and every member
stays within its SLO; group candidates are priced by the batched
multi-kernel solver through the shared `Scenario` currency.

Slot partitioning (the green-context analogue, paper §5.3) runs the
k-way slot-fraction search (`repro.core.fracsearch`) for SLO-violating
groups: coarse simplex fraction vectors plus a sensitivity-guided
refinement step, every (group x fraction-vector) candidate priced in one
deduplicated batched solve.  Partitioned pairs grow into partitioned
k-way groups the same way full-share pairs do (each candidate group
re-searches its fractions), with the best fractions cached in ``_group``
alongside the gains.  ``FractionSearchConfig`` tunes the search;
``LEGACY_SEARCH`` (coarse-only, no partitioned growth) reproduces the
seed planner's fixed first-member grid bit-for-bit.

``plan_colocation`` / ``evaluate_pair`` / ``evaluate_pair_partitioned``
remain as deprecated thin wrappers (a cold scheduler with
``max_group_size=2`` and ``LEGACY_SEARCH`` reproduces their output
exactly; pinned by tests).
"""
from __future__ import annotations

import heapq
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.backend import warmup_solver
from repro.core.estimator import FRACTION_FLOOR, solve_batch, solve_scenarios
from repro.core.fracsearch import (LEGACY_SEARCH, FractionSearchConfig,
                                   group_metrics, member_slowdowns,
                                   search_group_fractions,
                                   simplex_candidates)
from repro.core.profile import KernelProfile, ProfileMatrix, WorkloadProfile
from repro.core.resources import DeviceModel
from repro.core.scenario import Scenario, group_victim_scenarios

# the legacy pair grid — identical to the k=2 coarse simplex at 4 steps
# (kept as the deprecated `evaluate_pair_partitioned` shim's default)
_PARTITION_FRACTIONS = (0.25, 0.5, 0.75)
_PAIR_BLOCK = 16384          # pairs per batched solve: bounds peak memory


@dataclass
class Placement:
    workloads: List[str]
    slot_fraction: Dict[str, float]
    predicted_slowdown: Dict[str, float]
    meets_slo: bool
    throughput_gain: float       # vs running members serially

    def __repr__(self):
        mems = " + ".join(self.workloads)
        slow = ", ".join(f"{k}:{v:.2f}x" for k, v in self.predicted_slowdown.items())
        return (f"<Placement [{mems}] slow=({slow}) "
                f"gain={self.throughput_gain:.2f} slo_ok={self.meets_slo}>")


def _rep_kernel(w: WorkloadProfile, dev: DeviceModel) -> KernelProfile:
    """Time-weighted aggregate kernel used for quick pair screening."""
    return w.representative_kernel(dev)


def _pair_metrics(ta, tb, ra, rb, slo_a, slo_b):
    """Vectorized two-member `fracsearch.group_metrics` (array-of-pairs
    form) for _PairEvaluator's hot path — same floor, same comparisons."""
    gain = (ta + tb) / np.maximum(np.maximum(ta * ra, tb * rb), 1e-12)
    meets = (ra <= slo_a) & (rb <= slo_b)
    return gain, meets


# ------------------------------------------------------------------ #
#  Group evaluation (k >= 2): the scalar twin of the scheduler's       #
#  batched group pricing — shared member-slowdown/gain definitions     #
# ------------------------------------------------------------------ #
def evaluate_group(workloads: Sequence[WorkloadProfile], dev: DeviceModel,
                   slot_fraction: Optional[Dict[str, float]] = None
                   ) -> Placement:
    """Price one candidate group: every member's workload-level slowdown
    against the other members' representative kernels (one batched solve
    over the shared `group_victim_scenarios` probe set), packed gain =
    serial time / colocated makespan, SLO feasibility of all members.
    For two members this is exactly the legacy ``evaluate_pair``."""
    works = list(workloads)
    reps = {w.name: w.representative_kernel(dev) for w in works}
    scenarios = group_victim_scenarios(works, reps, slot_fraction)
    if scenarios:
        victim_slows = solve_scenarios(scenarios, dev).slowdowns[:, 0]
    else:
        victim_slows = np.zeros(0)
    slows = member_slowdowns(works, dev, victim_slows)
    gain, meets = group_metrics([w.total_time(dev) for w in works],
                                [slows[w.name] for w in works],
                                [w.slo_slowdown for w in works])
    return Placement([w.name for w in works], dict(slot_fraction or {}),
                     {n: float(s) for n, s in slows.items()}, meets, gain)


def evaluate_group_partitioned(workloads: Sequence[WorkloadProfile],
                               dev: DeviceModel,
                               fractions: Optional[Sequence[float]] = None,
                               *, search: Optional[FractionSearchConfig] = None
                               ) -> Placement:
    """Full sharing first, then slot partitions (green contexts) via the
    k-way slot-fraction search: coarse simplex fraction vectors plus a
    sensitivity-guided refinement step, all candidates priced in one
    deduplicated batched solve (`repro.core.fracsearch`).

    ANY SLO-meeting partition beats an infeasible full-share placement,
    regardless of its gain (the legacy ``gain > 0`` comparison discarded
    feasible non-positive-gain partitions).

    ``fractions`` is the DEPRECATED legacy grid: explicit first-member
    fractions, the other members splitting the complement evenly, priced
    without refinement (what the ``evaluate_pair_partitioned`` shim
    forwards — bit-identical to the seed).  Tune the full search with
    ``search=FractionSearchConfig(...)`` instead.
    """
    works = list(workloads)
    best = evaluate_group(works, dev)
    if best.meets_slo:
        return best
    names = [w.name for w in works]
    if fractions is not None:
        rest = max(len(works) - 1, 1)
        cands = [[(f,) + ((1.0 - f) / rest,) * rest for f in fractions]]
        res = search_group_fractions([works], dev, LEGACY_SEARCH,
                                     candidates=cands)[0]
    else:
        res = search_group_fractions([works], dev, search)[0]
    if res.meets_slo:
        return Placement(names, dict(zip(names, map(float, res.fractions))),
                         {n: float(s) for n, s in res.slowdowns.items()},
                         True, float(res.gain))
    return best


# ------------------------------------------------------------------ #
#  Deprecated one-shot API (thin wrappers; see ColocationScheduler)    #
# ------------------------------------------------------------------ #
def evaluate_pair(a: WorkloadProfile, b: WorkloadProfile, dev: DeviceModel,
                  slot_fraction: Optional[Dict[str, float]] = None
                  ) -> Placement:
    """Deprecated: use ``evaluate_group([a, b], dev, slot_fraction)``."""
    warnings.warn("evaluate_pair is deprecated; use evaluate_group",
                  DeprecationWarning, stacklevel=2)
    return evaluate_group((a, b), dev, slot_fraction)


def evaluate_pair_partitioned(a: WorkloadProfile, b: WorkloadProfile,
                              dev: DeviceModel,
                              fractions: Sequence[float] = _PARTITION_FRACTIONS
                              ) -> Placement:
    """Deprecated: use ``evaluate_group_partitioned([a, b], dev)``."""
    warnings.warn("evaluate_pair_partitioned is deprecated; use "
                  "evaluate_group_partitioned", DeprecationWarning,
                  stacklevel=2)
    return evaluate_group_partitioned((a, b), dev, fractions)


class _PairEvaluator:
    """Batched pair evaluation over a fixed workload set — the dense
    array fast path of the `Scenario` currency (same victims-first
    member convention, no per-scenario Python objects on the O(n^2)
    pricing path).

    Compiles every workload kernel + representative background kernel into
    one ProfileMatrix and flat per-kernel arrays, so evaluating a block of
    pairs is pure array arithmetic: scenario (kernel_row, rep_row) index
    pairs come from a ragged gather over kernel counts, one `solve_batch`
    call prices them all, and workload-level slowdowns aggregate back with
    a segmented sum. No per-pair Python estimator work remains."""

    def __init__(self, works: Sequence[WorkloadProfile], dev: DeviceModel,
                 reps: Optional[Sequence[KernelProfile]] = None):
        self.works = list(works)
        self.dev = dev
        self.scenarios_solved = 0
        n = len(self.works)
        profiles: List[KernelProfile] = []
        counts, weights = [], []
        for w in self.works:
            counts.append(len(w.kernels))
            for k in w.kernels:
                profiles.append(k)
                weights.append(k.isolated_time(dev) * k.duration_weight)
        self.counts = np.asarray(counts, np.int64)
        self.offsets = np.concatenate(([0], np.cumsum(self.counts[:-1])))
        self.kernel_weight = np.asarray(weights, np.float64)
        self.rep_rows = np.arange(n, dtype=np.int64) + len(profiles)
        # callers holding memoized representative kernels (the scheduler's
        # per-workload cache) pass them in; recomputing gives identical
        # profiles, just redundantly
        if reps is None:
            reps = [_rep_kernel(w, dev) for w in self.works]
        profiles.extend(reps)
        self.pm = ProfileMatrix.from_profiles(profiles)
        self.totals = np.asarray([w.total_time(dev) for w in self.works])
        self.slos = np.asarray([w.slo_slowdown for w in self.works])
        # slot-fraction dicts are keyed by KERNEL name (estimate()'s
        # contract): a member kernel only picks up a workload's fraction
        # if its name coincides with that workload's name — matching the
        # seed's evaluate_pair semantics exactly
        name_to_w = {w.name: wi for wi, w in enumerate(self.works)}
        self.kernel_name_w = np.asarray(
            [name_to_w.get(k.name, -1)
             for w in self.works for k in w.kernels], np.int64)

    def evaluate(self, ia: np.ndarray, ib: np.ndarray, frac=None):
        """Slowdowns/gain/SLO arrays for pairs (ia[p], ib[p]); `frac`
        gives workload ia a slot fraction and ib its own: a scalar f
        means (f, 1-f) — evaluate_pair's legacy convention — and a
        (fa, fb) pair of scalars or per-pair arrays prices an arbitrary
        fraction vector per pair (None = full sharing)."""
        P = len(ia)
        if frac is not None:
            fa, fb = (frac, 1.0 - frac) if np.isscalar(frac) else frac
            fa = np.broadcast_to(np.asarray(fa, np.float64), (P,))
            fb = np.broadcast_to(np.asarray(fb, np.float64), (P,))
            frac = (fa, fb)
        ra = np.empty(P)
        rb = np.empty(P)
        for lo in range(0, P, _PAIR_BLOCK):
            hi = min(lo + _PAIR_BLOCK, P)
            blk = None if frac is None else (frac[0][lo:hi], frac[1][lo:hi])
            ra[lo:hi], rb[lo:hi] = self._block(ia[lo:hi], ib[lo:hi], blk)
        gain, meets = _pair_metrics(self.totals[ia], self.totals[ib], ra, rb,
                                    self.slos[ia], self.slos[ib])
        return ra, rb, gain, meets

    def _probe_side(self, probed, other, frac_probed, frac_other):
        """Scenarios probing `probed`'s kernels against `other`'s rep.
        `frac_probed`/`frac_other` are per-pair arrays (or None)."""
        cnt = self.counts[probed]
        owner = np.repeat(np.arange(len(probed)), cnt)
        start = np.repeat(np.cumsum(cnt) - cnt, cnt)
        krow = np.repeat(self.offsets[probed], cnt) \
            + np.arange(cnt.sum()) - start
        members = np.stack([krow, np.repeat(self.rep_rows[other], cnt)], 1)
        if frac_probed is None:
            fr = None
        else:
            # the probed kernel matches the sf dict only by name identity
            kw = self.kernel_name_w[krow]
            fp = np.repeat(frac_probed, cnt)
            fo = np.repeat(frac_other, cnt)
            f0 = np.where(kw == np.repeat(probed, cnt), fp,
                          np.where(kw == np.repeat(other, cnt), fo, 1.0))
            fr = np.stack([f0, fo], 1)
        return members, fr, owner, self.kernel_weight[krow]

    def _block(self, ia, ib, frac):
        m_a, f_a, own_a, w_a = self._probe_side(
            ia, ib, *((None, None) if frac is None else frac))
        m_b, f_b, own_b, w_b = self._probe_side(
            ib, ia, *((None, None) if frac is None else (frac[1], frac[0])))
        members = np.concatenate([m_a, m_b])
        fractions = None if frac is None else np.concatenate([f_a, f_b])
        self.scenarios_solved += len(members)
        br = solve_batch(self.pm, members, self.dev, fractions)
        slow = br.slowdowns[:, 0] * np.concatenate([w_a, w_b])
        P = len(ia)
        na, nb = len(m_a), len(m_b)
        ra = np.bincount(own_a, slow[:na], minlength=P) \
            / np.maximum(self.totals[ia], 1e-12)
        rb = np.bincount(own_b, slow[na:na + nb], minlength=P) \
            / np.maximum(self.totals[ib], 1e-12)
        return ra, rb


@dataclass
class Plan:
    placements: List[Placement]
    solo: List[str]

    @property
    def total_gain(self) -> float:
        """Mean packed-throughput gain per occupied device: each placement
        contributes its members' predicted gain (serial time / colocated
        makespan), each solo workload contributes 1.0."""
        devices = len(self.placements) + len(self.solo)
        if devices == 0:
            return 1.0
        gains = sum(p.throughput_gain for p in self.placements)
        return (gains + len(self.solo)) / devices


# price tuples, ordered by the members' (stable) arrival positions:
# pair -> (slow_lo, slow_hi, gain, meets, frac_lo, frac_hi) with NaN
# fractions meaning full sharing; group -> (gain, meets, slows,
# fractions) with an empty fraction dict for full-share groups
_PairPrice = Tuple[float, float, float, bool, float, float]
_GroupPrice = Tuple[float, bool, Dict[str, float], Dict[str, float]]


class ColocationScheduler:
    """Online k-way interference-aware colocation scheduler.

    >>> sched = ColocationScheduler(dev, max_group_size=3)
    >>> sched.submit(decode); sched.submit(prefill)
    >>> plan = sched.plan()          # prices the new pairs, places
    >>> sched.remove("decode")       # zero estimator work
    >>> plan = sched.plan()          # replays greedy over cached prices

    SLO-violating pairs fall back to slot partitioning via the k-way
    fraction search (``fraction_search`` tunes it; see
    ``FractionSearchConfig``), and partitioned pairs grow into
    partitioned k-way groups exactly like full-share pairs do — each
    candidate group re-searches its fraction vector, cached in
    ``_group`` alongside the gain.

    Pricing is lazy: ``submit``/``remove`` are O(1) bookkeeping, and the
    next ``plan()`` prices exactly the pairs that have never been priced
    (one batched solve). ``stats["scenarios_solved"]`` counts estimator
    scenarios, the unit the O(n)-per-arrival guarantee is stated in
    (tracked by the churn benchmark).
    """

    def __init__(self, dev: DeviceModel, max_group_size: int = 2,
                 allow_partition: bool = True,
                 fraction_search: Optional[FractionSearchConfig] = None,
                 warmup: bool = False):
        if max_group_size < 2:
            raise ValueError("max_group_size must be >= 2")
        self.dev = dev
        self.max_group_size = int(max_group_size)
        self.allow_partition = allow_partition
        # default: backend-resolved (coarse simplex + 1 refinement level
        # on numpy; the denser DENSE_SEARCH grid on the jax backend);
        # LEGACY_SEARCH reproduces the seed's fixed grid
        self.search = fraction_search or FractionSearchConfig.default()
        if warmup:
            # opt-in AOT compile of the jax solver's common shapes (K up
            # to the group width this scheduler prices; no-op on numpy)
            warmup_solver(dev, ks=range(2, self.max_group_size + 1))
        self._works: Dict[str, WorkloadProfile] = {}   # insertion-ordered
        self._uid: Dict[str, int] = {}
        self._next_uid = 0
        self._pair: Dict[Tuple[int, int], _PairPrice] = {}
        # keyed by (sorted member uids, "full" | "part"): the same uid
        # set can hold both a full-share and a partitioned price
        self._group: Dict[Tuple[Tuple[int, ...], str], _GroupPrice] = {}
        self._reps: Dict[int, KernelProfile] = {}
        self.stats: Dict[str, int] = {
            "scenarios_solved": 0, "pairs_priced": 0, "groups_priced": 0,
            "arrivals": 0, "departures": 0,
        }

    # ----------------------------- events ------------------------- #
    def __len__(self) -> int:
        return len(self._works)

    def __contains__(self, name: str) -> bool:
        return name in self._works

    @property
    def workloads(self) -> List[WorkloadProfile]:
        """Current pool in arrival order."""
        return list(self._works.values())

    def submit(self, workload: WorkloadProfile) -> None:
        """Admit (or update) a workload. Re-submitting an existing name
        replaces its profile but keeps its arrival position (the legacy
        planner's last-profile-wins dedup); its cached prices are
        invalidated. O(1) — pricing happens lazily at the next plan()."""
        old_uid = self._uid.get(workload.name)
        if old_uid is not None:
            self._drop_prices(old_uid)
        self._works[workload.name] = workload
        self._uid[workload.name] = self._next_uid
        self._next_uid += 1
        self.stats["arrivals"] += 1

    def remove(self, name: str) -> None:
        """Retire a workload. Its pair/group prices are dropped; every
        other price stays valid (a pair's slowdown is independent of the
        rest of the pool), so the survivors of its group re-enter the
        pool with zero pairwise re-pricing (k>2 replays may price fresh
        group combinations on the next plan).

        Removing an unknown name raises ``KeyError`` BEFORE any state is
        touched — the pool, the pricing cache, and the next ``plan()``
        are exactly what they were (pinned online==cold by tests)."""
        if name not in self._works:
            raise KeyError(f"unknown workload: {name!r}")
        uid = self._uid.pop(name)
        del self._works[name]
        self._drop_prices(uid)
        self.stats["departures"] += 1

    def drain(self) -> List[WorkloadProfile]:
        """Retire EVERY workload at once and return them in arrival
        order — the fleet-migration hook: when a device dies or is
        decommissioned, its scheduler drains and the returned pool is
        re-placed on the survivors (repro.core.fleet).  All cached
        prices are dropped; the scheduler is reusable afterwards (a
        later submit starts a fresh pool)."""
        pool = list(self._works.values())
        self._works.clear()
        self._uid.clear()
        self._pair.clear()
        self._group.clear()
        self._reps.clear()
        self.stats["departures"] += len(pool)
        return pool

    def snapshot(self) -> Dict:
        """Read-only state summary (fleet telemetry / debugging): the
        resident pool in arrival order, cache occupancy, and a copy of
        the stats counters.  Never triggers pricing."""
        return {
            "workloads": [w.name for w in self._works.values()],
            "cached_pairs": len(self._pair),
            "cached_groups": len(self._group),
            "max_group_size": self.max_group_size,
            "stats": dict(self.stats),
        }

    def _drop_prices(self, uid: int) -> None:
        self._reps.pop(uid, None)
        for key in [k for k in self._pair if uid in k]:
            del self._pair[key]
        for key in [k for k in self._group if uid in k[0]]:
            del self._group[key]

    def _rep(self, name: str) -> KernelProfile:
        uid = self._uid[name]
        rep = self._reps.get(uid)
        if rep is None:
            rep = self._reps[uid] = self._works[name].representative_kernel(
                self.dev)
        return rep

    # ----------------------------- pricing ------------------------ #
    def _price_missing_pairs(self, works: List[WorkloadProfile],
                             uids: List[int]) -> None:
        """One batched solve over every never-priced pair (an arrival's
        new row; the full triangle on a cold start)."""
        n = len(works)
        missing = [(i, j) for j in range(n) for i in range(j)
                   if (uids[i], uids[j]) not in self._pair]
        if not missing:
            return
        ev = _PairEvaluator(works, self.dev,
                            reps=[self._rep(w.name) for w in works])
        ia = np.fromiter((i for i, _ in missing), np.int64, len(missing))
        ib = np.fromiter((j for _, j in missing), np.int64, len(missing))
        ra, rb, gain, meets = ev.evaluate(ia, ib)       # full-sharing pass
        fa = np.full(len(ia), np.nan)                   # nan = full sharing
        fb = np.full(len(ia), np.nan)

        if self.allow_partition:
            failing = np.flatnonzero(~meets)
            if failing.size:
                bra, brb, bgain, bmeets, bfa, bfb = self._search_pair_fractions(
                    ev, ia[failing], ib[failing])
                sel = failing[bmeets]
                ra[sel], rb[sel] = bra[bmeets], brb[bmeets]
                gain[sel] = bgain[bmeets]
                meets[sel] = True
                fa[sel], fb[sel] = bfa[bmeets], bfb[bmeets]

        for p, (i, j) in enumerate(missing):
            self._pair[(uids[i], uids[j])] = (
                float(ra[p]), float(rb[p]), float(gain[p]), bool(meets[p]),
                float(fa[p]), float(fb[p]))
        self.stats["scenarios_solved"] += ev.scenarios_solved
        self.stats["pairs_priced"] += len(missing)

    def _search_pair_fractions(self, ev: _PairEvaluator, fia: np.ndarray,
                               fib: np.ndarray):
        """The k=2 slot-fraction search on the DENSE pair-evaluator path:
        the green-context fallback for SLO-violating pairs, array-
        vectorized across all failing pairs per candidate vector (no
        per-probe Python objects on the O(n^2) pricing hot path).

        Selection and refinement mirror `fracsearch` exactly — feasible
        max-gain (earliest candidate on ties; ANY feasible partition
        beats the infeasible full share), least-violating anchor
        otherwise, refinement moving delta toward the binding member —
        and tests pin this path against `search_group_fractions` and the
        scalar oracle at 1e-9.  Keep the two in lockstep."""
        F = len(fia)
        slo_a, slo_b = ev.slos[fia], ev.slos[fib]
        ta, tb = ev.totals[fia], ev.totals[fib]
        bmeets = np.zeros(F, bool)
        bgain = np.full(F, -np.inf)
        bviol = np.full(F, np.inf)
        bra = np.empty(F)
        brb = np.empty(F)
        bfa = np.empty(F)
        bfb = np.empty(F)

        def consider(valid, f1, f2):
            cra, crb, cgain, cmeets = ev.evaluate(fia, fib, frac=(f1, f2))
            viol = np.maximum(cra / np.maximum(slo_a, 1e-12),
                              crb / np.maximum(slo_b, 1e-12))
            take = valid & ((cmeets & ~bmeets)
                            | (cmeets & bmeets & (cgain > bgain))
                            | (~cmeets & ~bmeets & (viol < bviol)))
            for dst, src in ((bmeets, cmeets), (bgain, cgain),
                             (bviol, viol), (bra, cra), (brb, crb),
                             (bfa, f1), (bfb, f2)):
                dst[take] = np.broadcast_to(src, (F,))[take]

        steps = self.search.steps_for(2)
        every = np.ones(F, bool)
        for f1, f2 in simplex_candidates(2, steps):
            consider(every, np.full(F, f1), np.full(F, f2))
        for level in range(1, self.search.refine_levels + 1):
            delta = 1.0 / (steps * 2 ** level)
            # sensitivity guidance, the two-member specialization: move
            # delta toward the makespan owner (feasible) or the worse
            # SLO violator; argmax ties resolve to the first member
            recv_a = np.where(bmeets, ta * bra >= tb * brb,
                              bra / np.maximum(slo_a, 1e-12)
                              >= brb / np.maximum(slo_b, 1e-12))
            f1 = np.where(recv_a, bfa + delta, bfa - delta)
            f2 = np.where(recv_a, bfb - delta, bfb + delta)
            donor_left = np.where(recv_a, bfb, bfa) - delta
            consider(donor_left > FRACTION_FLOOR, f1, f2)
        return bra, brb, bgain, bmeets, bfa, bfb

    def _price_groups(self, works: List[WorkloadProfile], uids: List[int],
                      group: List[int], cands: List[int],
                      partitioned: bool = False) -> List[_GroupPrice]:
        """Price group+{c} for every candidate c in ONE batched pass via
        the Scenario currency: each member kernel is a victim against the
        other members' representative kernels (the same probe the
        pairwise matrix uses, widened to k members).  Partitioned groups
        run the k-way slot-fraction search instead of a full-share solve
        and cache their best fractions alongside the gain.  Members are
        priced in canonical works-index order, so a cached price never
        depends on the greedy path that first produced it."""
        mode = "part" if partitioned else "full"

        def key(c: int) -> Tuple[Tuple[int, ...], str]:
            return tuple(sorted(uids[m] for m in group + [c])), mode

        missing = [c for c in cands if key(c) not in self._group]
        if missing:
            member_sets = [sorted(group + [c]) for c in missing]
            reps = {works[m].name: self._rep(works[m].name)
                    for g in member_sets for m in g}
            if partitioned:
                found = search_group_fractions(
                    [[works[m] for m in g] for g in member_sets],
                    self.dev, self.search, reps=reps, stats=self.stats)
                for g, r in zip(member_sets, found):
                    names = [works[m].name for m in g]
                    self._group[(tuple(sorted(uids[m] for m in g)), mode)] = (
                        float(r.gain), bool(r.meets_slo),
                        {n: float(s) for n, s in r.slowdowns.items()},
                        dict(zip(names, map(float, r.fractions)))
                        if r.meets_slo else {})
            else:
                scenarios: List[Scenario] = []
                for g in member_sets:
                    scenarios.extend(group_victim_scenarios(
                        [works[m] for m in g], reps, device=self.dev))
                br = solve_scenarios(scenarios)
                self.stats["scenarios_solved"] += len(scenarios)
                row = 0
                for g in member_sets:
                    members = [works[m] for m in g]
                    n_rows = sum(len(w.kernels) for w in members)
                    slows = member_slowdowns(
                        members, self.dev, br.slowdowns[row:row + n_rows, 0])
                    row += n_rows
                    gain, meets = group_metrics(
                        [w.total_time(self.dev) for w in members],
                        [slows[w.name] for w in members],
                        [w.slo_slowdown for w in members])
                    self._group[(tuple(sorted(uids[m] for m in g)), mode)] = (
                        gain, meets, slows, {})
            self.stats["groups_priced"] += len(missing)
        return [self._group[key(c)] for c in cands]

    # ----------------------------- planning ----------------------- #
    def plan(self) -> Plan:
        """Current placements: greedy max-gain SLO-feasible grouping over
        the cached price matrix (prices any never-seen pairs first)."""
        works = list(self._works.values())
        names = [w.name for w in works]
        n = len(works)
        if n < 2:
            return Plan([], sorted(names))
        uids = [self._uid[nm] for nm in names]
        self._price_missing_pairs(works, uids)

        iu, ju = np.triu_indices(n, k=1)            # pairs in (i, j) lex order
        prices = [self._pair[(uids[i], uids[j])] for i, j in zip(iu, ju)]
        gain = np.fromiter((p[2] for p in prices), np.float64, len(prices))
        meets = np.fromiter((p[3] for p in prices), bool, len(prices))

        # greedy rounds over the cached matrix: max-heap keyed by
        # (gain desc, pair index asc) replays the seed's exact pick order;
        # placements invalidate their members' rows lazily (skip on pop)
        feas = np.flatnonzero(meets)
        heap = list(zip(-gain[feas], iu[feas], ju[feas], feas))
        heapq.heapify(heap)
        placed = np.zeros(n, bool)
        placements: List[Placement] = []
        while heap:
            neg_gain, i, j, p = heapq.heappop(heap)
            if placed[i] or placed[j]:
                continue
            if -neg_gain <= 1.0:
                break
            i, j = int(i), int(j)
            ra, rb, g, _, f_lo, f_hi = prices[int(p)]
            group = [i, j]
            slows = {names[i]: ra, names[j]: rb}
            if np.isnan(f_lo):
                sf: Dict[str, float] = {}
            else:
                sf = {names[i]: f_lo, names[j]: f_hi}
            if self.max_group_size > 2 and (
                    np.isnan(f_lo) or self.search.grow_partitioned):
                group, slows, g, sf = self._grow(
                    works, uids, placed, group, slows, g,
                    None if np.isnan(f_lo) else sf)
            placements.append(Placement(
                [names[m] for m in group], sf,
                {nm: float(s) for nm, s in slows.items()}, True, float(g)))
            placed[group] = True
        solo = sorted(names[i] for i in np.flatnonzero(~placed))
        return Plan(placements, solo)

    def place_candidates(self, workload: WorkloadProfile) -> List[Placement]:
        """Price ``workload`` against this device's CURRENT placement —
        without mutating any scheduler state — and return one candidate
        ``Placement`` per way it could land here: each current group
        with an open slot, each solo resident, plus running alone on the
        device (gain 1.0, always last among equals).  Candidates are
        sorted by gain descending (stable: current-plan order on ties);
        infeasible joins are included with ``meets_slo=False`` so a
        caller can see WHY a device was rejected.

        This is the per-device incremental entry point fleet-level
        repair planning needs: "what would adding this workload to this
        device cost?" answered from the resident groups the cached plan
        already holds, with one batched solve over the probe scenarios
        (and one batched fraction search over SLO-failing joins when
        ``allow_partition``).  The probe workload is NOT admitted and
        nothing is cached under its name — ``submit()`` it to accept a
        candidate.  Raises ``ValueError`` if the name is already
        resident (re-pricing a resident is a resubmit, not a probe)."""
        if workload.name in self._works:
            raise ValueError(f"already resident: {workload.name!r}")
        plan = self.plan()      # prices any never-seen pairs, from cache
        host_groups: List[List[WorkloadProfile]] = []
        for p in plan.placements:
            if len(p.workloads) < self.max_group_size:
                host_groups.append([self._works[n] for n in p.workloads])
        for n in plan.solo:
            host_groups.append([self._works[n]])
        reps = {n: self._rep(n) for g in host_groups for w in g
                for n in (w.name,)}
        reps[workload.name] = workload.representative_kernel(self.dev)
        cand = [g + [workload] for g in host_groups]
        scenarios: List[Scenario] = []
        for g in cand:
            scenarios.extend(group_victim_scenarios(g, reps,
                                                    device=self.dev))
        out: List[Placement] = []
        failing: List[int] = []
        if scenarios:
            br = solve_scenarios(scenarios, self.dev)
            self.stats["scenarios_solved"] += len(scenarios)
            row = 0
            for g in cand:
                n_rows = sum(len(w.kernels) for w in g)
                slows = member_slowdowns(g, self.dev,
                                         br.slowdowns[row:row + n_rows, 0])
                row += n_rows
                gain, meets = group_metrics(
                    [w.total_time(self.dev) for w in g],
                    [slows[w.name] for w in g],
                    [w.slo_slowdown for w in g])
                out.append(Placement(
                    [w.name for w in g], {},
                    {n: float(s) for n, s in slows.items()},
                    bool(meets), float(gain)))
                if not meets:
                    failing.append(len(out) - 1)
        if failing and self.allow_partition:
            found = search_group_fractions([cand[i] for i in failing],
                                           self.dev, self.search, reps=reps,
                                           stats=self.stats)
            for i, r in zip(failing, found):
                if r.meets_slo:
                    names = [w.name for w in cand[i]]
                    out[i] = Placement(
                        names, dict(zip(names, map(float, r.fractions))),
                        {n: float(s) for n, s in r.slowdowns.items()},
                        True, float(r.gain))
        out.append(Placement([workload.name], {}, {workload.name: 1.0},
                             True, 1.0))
        out.sort(key=lambda p: -p.throughput_gain)
        return out

    def _grow(self, works, uids, placed, group, slows, gain, fractions):
        """Greedy group growth: add the unplaced workload that most
        improves the packed gain while keeping every member (old and new)
        within SLO; stop at max_group_size or when no candidate helps.
        ``fractions`` None grows at full sharing; a fraction dict grows a
        PARTITIONED group — every candidate group re-runs the slot-
        fraction search, and the accepted candidate's best fractions
        replace the group's."""
        partitioned = fractions is not None
        while len(group) < self.max_group_size:
            cands = [c for c in range(len(works))
                     if not placed[c] and c not in group]
            if not cands:
                break
            priced = self._price_groups(works, uids, group, cands,
                                        partitioned)
            best = None
            for c, (cg, cmeets, cslows, cfracs) in zip(cands, priced):
                if cmeets and cg > gain and (best is None or cg > best[1]):
                    best = (c, cg, cslows, cfracs)
            if best is None:
                break
            group.append(best[0])
            gain, slows = best[1], best[2]
            if partitioned:
                fractions = best[3]
        return group, slows, gain, dict(fractions or {})


def plan_colocation(workloads: Sequence[WorkloadProfile], dev: DeviceModel,
                    allow_partition: bool = True) -> Plan:
    """Deprecated one-shot pairing: a cold ``ColocationScheduler`` with
    ``max_group_size=2`` and the legacy fixed-grid fraction search
    (identical plans, pinned by tests)."""
    warnings.warn("plan_colocation is deprecated; use ColocationScheduler "
                  "(submit/remove/plan)", DeprecationWarning, stacklevel=2)
    sched = ColocationScheduler(dev, max_group_size=2,
                                allow_partition=allow_partition,
                                fraction_search=LEGACY_SEARCH)
    for w in workloads:
        sched.submit(w)          # dedup: last profile wins, first position
    return sched.plan()
