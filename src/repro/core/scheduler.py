"""Interference-aware colocation planner (paper §5.1).

Given workload profiles with SLOs, the planner:
  1. builds the pairwise predicted-slowdown matrix with the estimator
     (per-kernel granularity -> workload-level aggregation),
  2. greedily pairs workloads to maximize packed throughput subject to
     every member staying within its SLO slowdown,
  3. optionally allocates slot partitions (the green-context analogue:
     disjoint chip/core fractions) when full-device sharing violates an
     SLO but partitioned sharing does not — trading marginal per-workload
     performance for colocation opportunity (paper §5.3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.estimator import estimate, workload_slowdown
from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import DeviceModel


@dataclass
class Placement:
    workloads: List[str]
    slot_fraction: Dict[str, float]
    predicted_slowdown: Dict[str, float]
    meets_slo: bool
    throughput_gain: float       # vs running members serially

    def __repr__(self):
        mems = " + ".join(self.workloads)
        slow = ", ".join(f"{k}:{v:.2f}x" for k, v in self.predicted_slowdown.items())
        return (f"<Placement [{mems}] slow=({slow}) "
                f"gain={self.throughput_gain:.2f} slo_ok={self.meets_slo}>")


def _rep_kernel(w: WorkloadProfile, dev: DeviceModel) -> KernelProfile:
    """Time-weighted aggregate kernel used for quick pair screening."""
    u = w.mixed_utilization(dev)
    t = w.total_time(dev)
    return KernelProfile(w.name, demand={
        r: u[r] * dev.capacity(r) * t for r in u})


def evaluate_pair(a: WorkloadProfile, b: WorkloadProfile, dev: DeviceModel,
                  slot_fraction: Optional[Dict[str, float]] = None
                  ) -> Placement:
    ra = workload_slowdown(a, [_rep_kernel(b, dev)], dev, slot_fraction)
    rb = workload_slowdown(b, [_rep_kernel(a, dev)], dev, slot_fraction)
    slows = {a.name: ra, b.name: rb}
    ta, tb = a.total_time(dev), b.total_time(dev)
    serial = ta + tb
    colocated = max(ta * ra, tb * rb)
    gain = serial / max(colocated, 1e-12)
    return Placement([a.name, b.name], slot_fraction or {}, slows,
                     ra <= a.slo_slowdown and rb <= b.slo_slowdown, gain)


def evaluate_pair_partitioned(a: WorkloadProfile, b: WorkloadProfile,
                              dev: DeviceModel,
                              fractions: Sequence[float] = (0.25, 0.5, 0.75)
                              ) -> Placement:
    """Try full sharing first, then slot partitions (green contexts)."""
    best = evaluate_pair(a, b, dev)
    if best.meets_slo:
        return best
    for f in fractions:
        cand = evaluate_pair(a, b, dev, {a.name: f, b.name: 1.0 - f})
        if cand.meets_slo and cand.throughput_gain > (best.throughput_gain
                                                      if best.meets_slo else 0):
            best = cand
    return best


@dataclass
class Plan:
    placements: List[Placement]
    solo: List[str]

    @property
    def total_gain(self) -> float:
        n_works = sum(len(p.workloads) for p in self.placements) + len(self.solo)
        packed = len(self.placements) + len(self.solo)
        return n_works / max(packed, 1)


def plan_colocation(workloads: Sequence[WorkloadProfile], dev: DeviceModel,
                    allow_partition: bool = True) -> Plan:
    """Greedy max-gain SLO-feasible pairing."""
    remaining = {w.name: w for w in workloads}
    placements: List[Placement] = []
    while len(remaining) >= 2:
        names = list(remaining)
        best: Optional[Placement] = None
        for i in range(len(names)):
            for j in range(i + 1, len(names)):
                a, b = remaining[names[i]], remaining[names[j]]
                p = (evaluate_pair_partitioned(a, b, dev) if allow_partition
                     else evaluate_pair(a, b, dev))
                if p.meets_slo and (best is None
                                    or p.throughput_gain > best.throughput_gain):
                    best = p
        if best is None or best.throughput_gain <= 1.0:
            break
        placements.append(best)
        for n in best.workloads:
            remaining.pop(n)
    return Plan(placements, sorted(remaining))
