"""Scoped repair: group-aware incremental placement for the fleet.

``FleetScheduler`` historically recomputed the WHOLE assignment on every
mutation — a cold priority-ordered replay over all tracked workloads and
all live devices.  Correct and bit-reproducible, but quadratic-ish at
the fleet sizes ROADMAP item 1 targets: an arrival at 1000 devices
prices candidate groups on every device even though the placement it
lands on touches one.

This module is the scale path.  Every mutation now computes a
``RepairScope`` — the workloads that need (re)placement plus the devices
whose resident groups or queues the mutation touched — and the
``RepairPlanner`` replays placement ONLY within that scope:

  * the scoped greedy places each target workload (priority rank, then
    arrival order) on the max-gain feasible device among the scope's
    devices plus the ``repair_probe`` emptiest live devices, pricing
    through the same fleet-level deduplicated price cache the full
    replay uses;
  * devices that lost members (departure, death, migration away) are
    re-priced so the fleet's placement info stays exact;
  * the planner FALLS BACK to a full cold replay whenever the scope
    stops being local — the touched-device set exceeds
    ``full_replay_fraction`` of the live fleet — or whenever scoped
    repair cannot re-place an SLO workload (the cold greedy must get a
    chance to displace best-effort work before the workload queues).

**The bounded-divergence contract.**  A scoped repair keeps every
already-placed workload where it is, so the online assignment can
diverge from the cold replay — but only boundedly: the fleet's total
packed gain stays ≥ (1 − ε) × the cold replay's
(``FleetConfig.divergence_epsilon``), and the SET of placed SLO
workloads matches the cold replay exactly (guaranteed by the SLO
fallback rule).  ``benchmarks/bench_fleet.py`` gates both at scale;
``tests/test_repair.py`` property-tests them over random mutation
sequences.  With the default thresholds, fleets small enough that every
scope spans ≥ ``full_replay_fraction`` of the devices (≲ 32 with the
defaults) always take the full-replay path — the historical
``online == cold at 1e-9`` behavior is unchanged there.

The planner duck-types the fleet (``_tracked`` / ``_groups`` /
``_price`` / ``_live`` / ``devices`` / ``cfg``) so this module has no
import cycle with ``repro.core.fleet``; the shared lifecycle constants
live here and ``fleet`` re-exports them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# priority classes (admission order: SLO replays before best-effort)
SLO = "slo"
BEST_EFFORT = "best_effort"
_PRIORITY_RANK = {SLO: 0, BEST_EFFORT: 1}

# workload lifecycle states
PLACED = "placed"
QUEUED = "queued"
DEGRADED = "degraded"          # final: capacity genuinely insufficient

# device lifecycle states
D_HEALTHY = "healthy"
D_DEGRADED = "degraded"        # straggling: best-effort only
D_DEAD = "dead"


@dataclass(frozen=True)
class RepairScope:
    """What one mutation touched: the workloads needing (re)placement and
    the devices whose resident groups it may have changed.

    ``kind`` routes accounting ("arrival", "storm", "departure",
    "capacity", "device-dead", "device-degraded", "retry", or "full"
    for an unconditional cold replay); ``workloads``/``devices`` are
    insertion-ordered and deduplicated by construction at the call
    sites (the planner deduplicates again defensively)."""
    kind: str
    reason: str
    workloads: Tuple[str, ...] = ()
    devices: Tuple[str, ...] = ()

    @classmethod
    def full(cls, reason: str) -> "RepairScope":
        """A scope that unconditionally takes the cold-replay path."""
        return cls("full", reason)

    def merge(self, other: "RepairScope") -> "RepairScope":
        """Union two same-tick scopes (e.g. device death + due retries)."""
        if self.kind == "full" or other.kind == "full":
            return RepairScope.full(f"{self.reason}; {other.reason}")
        kind = (self.kind if self.kind == other.kind
                else f"{self.kind}+{other.kind}")
        return RepairScope(
            kind, f"{self.reason}; {other.reason}",
            self.workloads + tuple(w for w in other.workloads
                                   if w not in self.workloads),
            self.devices + tuple(d for d in other.devices
                                 if d not in self.devices))


@dataclass(frozen=True)
class RepairRecord:
    """Telemetry for one replan: how wide it was and what it cost.
    ``latency_s`` is wall-clock (NEVER feed it into deterministic
    reports — the touched counts are the reproducible metrics)."""
    kind: str
    reason: str
    full: bool                  # took the cold-replay path
    targets: int                # workloads the repair tried to (re)place
    devices_touched: int        # devices priced or modified
    latency_s: float


@dataclass
class RepairResult:
    """One computed (not yet applied) assignment.

    Full replays carry the COMPLETE new state: ``assign`` maps every
    live device to its member list and ``placement`` every placed
    workload to its device.  Scoped repairs carry a DELTA: ``assign``
    holds only modified devices, ``placement``/``unplaced`` only the
    scope's target workloads — everything else is untouched by
    construction.
    """
    full: bool
    assign: Dict[str, list]                 # device_id -> members
    info: Dict[str, Optional[tuple]]        # device_id -> price (None=empty)
    placement: Dict[str, str]               # workload name -> device_id
    targets: List[str] = field(default_factory=list)
    unplaced: list = field(default_factory=list)
    touched: Tuple[str, ...] = ()


class RepairPlanner:
    """Scope-aware placement over a ``FleetScheduler``'s state.

    ``plan()`` is the single replan entry point: it attempts a scoped
    repair when the fleet's ``repair_mode`` allows and the scope is
    local enough, and otherwise (or on any scoped bail-out) runs the
    cold full replay — the exact deterministic greedy the fleet has
    always used.  The planner reads fleet state but never mutates it;
    applying a ``RepairResult`` is the fleet's thin ``_apply`` layer.
    """

    def __init__(self, fleet):
        self.fleet = fleet

    # ------------------------------------------------------------- #
    def plan(self, scope: RepairScope,
             retry_due: frozenset = frozenset()) -> RepairResult:
        f = self.fleet
        cfg = f.cfg
        # eligibility: the fleet must be large enough that even a
        # probe-wide scope is local (live * fraction >= probe) — below
        # that (<= 32 devices with the defaults) every mutation takes
        # the full replay and the legacy online == cold at 1e-9
        # behavior is bit-preserved
        live_n = sum(1 for d in f.devices.values() if d.state != D_DEAD)
        eligible = (cfg.repair_mode == "scoped" and scope.kind != "full"
                    and live_n * cfg.full_replay_fraction
                    >= cfg.repair_probe)
        if eligible:
            res = self.scoped_repair(scope)
            if res is not None:
                f.stats["scoped_repairs"] += 1
                return res
            f.stats["repair_fallbacks"] += 1
        f.stats["full_replays"] += 1
        return self.full_replay(scope)

    # ------------------------------------------------------------- #
    def full_replay(self, scope: RepairScope) -> RepairResult:
        """The deterministic cold assignment: priority classes in order,
        arrival order within a class, each workload placed on the
        max-gain feasible device (earliest on ties) or left unplaced.
        Pure function of (tracked pool, device states, prices)."""
        f = self.fleet
        assign: Dict[str, list] = {
            d.device_id: [] for d in f.devices.values()
            if d.state != D_DEAD}
        info: Dict[str, Optional[tuple]] = {}
        unplaced: list = []
        order = sorted(f._tracked.values(),
                       key=lambda t: _PRIORITY_RANK[t.priority])
        for t in order:
            cands = [d for d in f._live(t.priority)
                     if len(assign[d.device_id]) < f.cfg.max_group_size]
            groups = [sorted(assign[d.device_id] + [t],
                             key=lambda x: x.pos) for d in cands]
            prices = f._price([(d.model, g)
                               for d, g in zip(cands, groups)])
            best = None
            for di, (gain, meets, _, _) in enumerate(prices):
                if meets and (best is None or gain > best[0]):
                    best = (gain, di)
            if best is None:
                unplaced.append(t)
            else:
                d = cands[best[1]]
                assign[d.device_id].append(t)
                info[d.device_id] = prices[best[1]]
        placement = {t.profile.name: did
                     for did, members in assign.items() for t in members}
        return RepairResult(
            full=True, assign=assign, info=info, placement=placement,
            targets=[t.profile.name for t in order], unplaced=unplaced,
            touched=tuple(assign))

    # ------------------------------------------------------------- #
    def scoped_repair(self, scope: RepairScope) -> Optional[RepairResult]:
        """Place only the scope's workloads, against only the scope's
        devices plus a bounded probe of the emptiest live devices.
        Returns ``None`` to demand the full-replay fallback: scope too
        wide (> ``full_replay_fraction`` of the live fleet) or an SLO
        target the scoped candidates cannot hold."""
        f = self.fleet
        cfg = f.cfg
        tracked = f._tracked
        live = {d.device_id: d for d in f.devices.values()
                if d.state != D_DEAD}
        if not live:
            return None

        # targets: scoped workloads still tracked, deduplicated, in the
        # replay's canonical order (priority rank, then arrival position)
        seen = set()
        targets = []
        for n in scope.workloads:
            if n in tracked and n not in seen:
                seen.add(n)
                targets.append(tracked[n])
        targets.sort(key=lambda t: (_PRIORITY_RANK[t.priority], t.pos))
        target_names = {t.profile.name for t in targets}

        # working copy of resident groups, dropping stale members (gone
        # from tracking, superseded by a resubmit, or targets being
        # re-placed); a device that lost members is modified and will be
        # re-priced even if it gains nothing back
        groups: Dict[str, list] = {}
        modified = set()
        for did in live:
            old = f._groups.get(did, [])
            keep = [t for t in old
                    if t.profile.name in tracked
                    and tracked[t.profile.name] is t
                    and t.profile.name not in target_names]
            groups[did] = keep
            if len(keep) != len(old):
                modified.add(did)

        # candidate devices: the scope's, plus the emptiest live devices
        # as migration targets (registry order breaks ties — the same
        # tie-break the full replay's earliest-device rule uses)
        cands: List[str] = [did for did in dict.fromkeys(scope.devices)
                            if did in live]
        if targets:
            reg_idx = {did: i for i, did in enumerate(f.devices)}
            probe = sorted((did for did in live if did not in cands),
                           key=lambda d: (len(groups[d]), reg_idx[d]))
            cands.extend(probe[:cfg.repair_probe])

        touched = set(cands) | modified
        if len(touched) > cfg.full_replay_fraction * len(live):
            return None

        info: Dict[str, Optional[tuple]] = {}
        placement: Dict[str, str] = {}
        unplaced: list = []
        for t in targets:
            ok = ((D_HEALTHY,) if t.priority == SLO
                  else (D_HEALTHY, D_DEGRADED))
            usable = [did for did in cands
                      if live[did].state in ok
                      and len(groups[did]) < cfg.max_group_size]
            cand_groups = [sorted(groups[did] + [t], key=lambda x: x.pos)
                           for did in usable]
            prices = f._price([(live[did].model, g)
                               for did, g in zip(usable, cand_groups)])
            touched.update(usable)
            best = None
            for di, (gain, meets, _, _) in enumerate(prices):
                if meets and (best is None or gain > best[0]):
                    best = (gain, di)
            if best is None:
                if t.priority == SLO:
                    # the cold greedy may displace best-effort work to
                    # hold an SLO tenant — scoped repair never evicts,
                    # so it must not be the one to queue an SLO workload
                    return None
                unplaced.append(t)
            else:
                did = usable[best[1]]
                groups[did].append(t)
                modified.add(did)
                info[did] = prices[best[1]]
                placement[t.profile.name] = did

        # re-price modified devices whose final group was never the one
        # just priced (lost members with no new arrival) in one batch
        resid = sorted(did for did in modified if did not in info)
        nonempty = [did for did in resid if groups[did]]
        for did, p in zip(nonempty, f._price(
                [(live[did].model, sorted(groups[did], key=lambda x: x.pos))
                 for did in nonempty])):
            info[did] = p
        for did in resid:
            if not groups[did]:
                info[did] = None

        return RepairResult(
            full=False,
            assign={did: groups[did] for did in sorted(modified)},
            info=info, placement=placement,
            targets=[t.profile.name for t in targets],
            unplaced=unplaced, touched=tuple(sorted(touched)))
