"""Process-wide solver-backend switch: NumPy oracle vs JAX-jitted solver.

Every scheduling decision in the repo — cold plans, arrival pricing, the
k-way fraction search, fleet replay, trace simulation — bottoms out in
the batched water-filling fixed point (`repro.core.estimator.solve_batch`).
This module selects which implementation executes it:

  * ``"numpy"`` (default): the reference implementation, retained
    verbatim as the 1e-9 oracle (same pattern as
    ``benchmarks/_seed_reference.py``);
  * ``"jax"``: the ``jax.jit``-compiled port in
    `repro.core.estimator_jax` (``lax.while_loop`` + ``vmap``, float64),
    which runs pricing on the accelerator it schedules for and is gated
    against the NumPy oracle at 1e-9 in CI.

Selection is process-wide: ``set_solver_backend("jax")`` (or the
``REPRO_SOLVER_BACKEND`` environment variable, read once at first use)
switches ColocationScheduler, fracsearch, FleetScheduler and the
serve/sim pricing in one place.  Consumers that *cache* the backend
choice at construction time (``FractionSearchConfig.default()``, the
scheduler's search config) pick up the backend active when they were
built — switch before constructing schedulers.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from typing import Iterator, Optional

SOLVER_BACKENDS = ("numpy", "jax")
_ENV_VAR = "REPRO_SOLVER_BACKEND"

_backend: Optional[str] = None      # resolved lazily from the env


def _validate(name: str) -> str:
    norm = str(name).strip().lower()
    if norm not in SOLVER_BACKENDS:
        raise ValueError(
            f"unknown solver backend {name!r}: expected one of "
            f"{SOLVER_BACKENDS}")
    return norm


def _ensure_jax() -> None:
    """Import the jax solver (enabling x64) or fail with a clear error —
    the numpy default never imports jax at all."""
    try:
        import repro.core.estimator_jax  # noqa: F401
    except ImportError as e:            # pragma: no cover - env-dependent
        raise RuntimeError(
            "solver backend 'jax' requested but jax is not importable; "
            "install jax or use set_solver_backend('numpy')") from e


def get_solver_backend() -> str:
    """The active solver backend name ("numpy" | "jax")."""
    global _backend
    if _backend is None:
        _backend = _validate(os.environ.get(_ENV_VAR, "numpy"))
        if _backend == "jax":
            _ensure_jax()
    return _backend


def set_solver_backend(name: str) -> str:
    """Select the solver backend process-wide; returns the PREVIOUS
    backend (so callers can restore it — or use `solver_backend`)."""
    global _backend
    prev = get_solver_backend()
    new = _validate(name)
    if new == "jax":
        _ensure_jax()
    _backend = new
    return prev


@contextmanager
def solver_backend(name: str) -> Iterator[str]:
    """Scoped backend override: ``with solver_backend("jax"): ...`` —
    restores the previous backend on exit (tests, benchmarks)."""
    prev = set_solver_backend(name)
    try:
        yield get_solver_backend()
    finally:
        set_solver_backend(prev)


def warmup_solver(dev, ks=(2, 3), buckets=None) -> int:
    """Ahead-of-time compile the jax solver's common padded shapes so a
    scheduler's first replans don't pay the ~0.8 s/shape XLA compile
    (ROADMAP item 2).  ``ks`` are the scenario widths to warm (group
    sizes: a k-member group's pricing scenarios are k wide); ``buckets``
    the padded batch sizes (default: the smallest bucket, which every
    small scheduler batch lands in).  Traces are keyed by shape only —
    device capacities are traced operands — so one warmup covers every
    device model.  Returns the number of NEW traces compiled; a no-op
    returning 0 on the numpy backend (schedulers can call it
    unconditionally)."""
    if get_solver_backend() != "jax":
        return 0
    from repro.core import estimator_jax
    kwargs = {} if buckets is None else {"buckets": tuple(buckets)}
    return estimator_jax.warmup(dev, ks=tuple(ks), **kwargs)
