from repro.train.optimizer import adafactor, adamw, get_optimizer  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig, make_train_step  # noqa: F401
