"""Train-step builder + Trainer loop.

``make_train_step`` builds the jit-able (params, opt_state, batch) ->
(params, opt_state, metrics) function with:
  * gradient accumulation over microbatches (lax.scan over batch splits),
  * optional int8-compressed gradient all-reduce (parallel/collectives),
  * remat policy inherited from the model config.

``Trainer`` (used by launch/train.py and examples) adds checkpointing,
auto-resume, straggler monitoring and throughput accounting.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models.model import Model
from repro.models.moe import LOCAL_CTX, ParallelContext
from repro.train.optimizer import Optimizer, get_optimizer


def _split_microbatches(batch: Dict[str, jnp.ndarray], k: int):
    def split(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by microbatches {k}"
        return x.reshape(k, b // k, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(model: Model, opt: Optimizer, run: RunConfig,
                    ctx: ParallelContext = LOCAL_CTX) -> Callable:
    k = run.num_microbatches

    def loss_of(params, mb):
        loss, metrics = model.loss_fn(params, mb, ctx)
        return loss, metrics

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            mbs = _split_microbatches(batch, k)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss / k
            metrics = {"ce": loss, "aux": jnp.zeros(())}
        if run.use_grad_compression:
            from repro.parallel.collectives import compress_grads_int8
            grads = compress_grads_int8(grads)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------- #
#  Trainer loop (host-side)                                              #
# --------------------------------------------------------------------- #
@dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    optimizer: str = "adamw"
    lr: Optional[float] = None
    straggler_factor: float = 3.0   # step slower than EWMA*factor => flag


class Trainer:
    def __init__(self, model: Model, run: RunConfig, tcfg: TrainerConfig,
                 ctx: ParallelContext = LOCAL_CTX, mesh=None,
                 shardings: Optional[Dict[str, Any]] = None):
        self.model = model
        self.run = run
        self.tcfg = tcfg
        self.ctx = ctx
        self.opt = get_optimizer(tcfg.optimizer, tcfg.lr, tcfg.total_steps)
        step_fn = make_train_step(model, self.opt, run, ctx)
        if shardings is not None:
            self.train_step = jax.jit(
                step_fn,
                in_shardings=(shardings["params"], shardings["opt"],
                              shardings["batch"]),
                out_shardings=(shardings["params"], shardings["opt"], None),
                donate_argnums=(0, 1))
        else:
            self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.ckpt_mgr = None
        if tcfg.checkpoint_dir:
            from repro.checkpoint import CheckpointManager
            self.ckpt_mgr = CheckpointManager(tcfg.checkpoint_dir,
                                              keep=tcfg.keep_checkpoints)
        from repro.ft import StragglerMonitor
        self.straggler = StragglerMonitor(factor=tcfg.straggler_factor)

    def init_state(self, key):
        params = self.model.init(key)
        return params, self.opt.init(params)

    def restore_or_init(self, key):
        params, opt_state = self.init_state(key)
        if self.ckpt_mgr is not None:
            restored = self.ckpt_mgr.restore_latest(like=(params, opt_state))
            if restored is not None:
                step, (params, opt_state) = restored
                return step + 1, params, opt_state
        return 0, params, opt_state

    def fit(self, data: Iterator, key=None, start_step: int = 0,
            params=None, opt_state=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        if params is None:
            start_step, params, opt_state = self.restore_or_init(key)
            if start_step:
                data.seek(start_step)
        history = []
        for step in range(start_step, self.tcfg.total_steps):
            batch = next(data)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.observe(step, dt)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                history.append((step, float(metrics["loss"]), dt))
                print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                      f"{dt * 1e3:.1f} ms")
            if (self.ckpt_mgr is not None and step > 0
                    and step % self.tcfg.checkpoint_every == 0):
                self.ckpt_mgr.save(step, (params, opt_state))
        if self.ckpt_mgr is not None:
            self.ckpt_mgr.save(self.tcfg.total_steps - 1, (params, opt_state),
                               block=True)
        return params, opt_state, history
