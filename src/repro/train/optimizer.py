"""Functional optimizers (no optax dependency): AdamW and Adafactor,
with global-norm clipping and warmup+cosine schedules.

State pytrees mirror the param pytree, so the parameter sharding specs
apply directly to the moments (ZeRO-3 optimizer-state sharding for free).
Adafactor keeps a factored second moment — the memory-sane choice for the
405B-class configs (see DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)
    name: str


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# --------------------------------------------------------------------- #
#  Schedules                                                             #
# --------------------------------------------------------------------- #
def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_ratio: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


# --------------------------------------------------------------------- #
#  AdamW                                                                 #
# --------------------------------------------------------------------- #
def adamw(lr: Callable | float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0, moment_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        step_lr = lr_fn(count)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            delta = delta + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - step_lr * delta
            return (p_new.astype(p.dtype), m_new.astype(moment_dtype),
                    v_new.astype(moment_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init, update, "adamw")


# --------------------------------------------------------------------- #
#  Adafactor (factored second moment, optional first moment)             #
# --------------------------------------------------------------------- #
def adafactor(lr: Callable | float = 1e-2, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, min_dim_factored: int = 128) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_factored
                and p.shape[-2] >= min_dim_factored)

    def init(params):
        def st(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"s": jax.tree.map(st, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c ** (-decay)
        step_lr = lr_fn(count)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if factored(p):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rms_r = vr / jnp.mean(vr, axis=-1, keepdims=True)
                u = gf / (jnp.sqrt(rms_r)[..., None] * jnp.sqrt(vc)[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = gf / jnp.sqrt(v)
                new_s = {"v": v}
            # update clipping (Adafactor-style RMS clip)
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            p_new = (p.astype(jnp.float32) - step_lr *
                     (u + weight_decay * p.astype(jnp.float32)))
            return p_new.astype(p.dtype), new_s

        is_state_leaf = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(upd, grads, state["s"], params,
                           is_leaf=lambda x: False)
        # out mirrors params with (p_new, state) tuples at param positions
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"s": new_s, "count": count}

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, lr=None, total_steps: int = 10000) -> Optimizer:
    sched = warmup_cosine(lr or (3e-4 if name == "adamw" else 1e-2),
                          warmup=min(1000, total_steps // 10) or 1,
                          total=total_steps)
    if name == "adamw":
        return adamw(sched)
    if name == "adafactor":
        return adafactor(sched)
    raise ValueError(name)
