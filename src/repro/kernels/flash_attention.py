"""Pallas TPU flash attention (forward) with GQA, causal/local masks.

Layout: q (BH, S, D) with BH = B * n_heads flattened; k/v (BKV, T, D).
Grid: (BH, n_q_blocks, n_kv_blocks) — the kv dimension is the minor,
sequential grid axis; m/l/acc live in VMEM scratch and persist across kv
steps (the standard TPU revisiting-output pattern). Block shapes are
(1, block_q, D) / (1, block_k, D): MXU-aligned when block_* are multiples
of 128 and D ∈ {64, 80, 128, 256}.

The pure-jnp oracle is ``repro.kernels.ref.ref_flash_attention``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, kind: str, window: int, block_q: int,
            block_k: int, n_kv_blocks: int, seq_q: int, seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # (bq, D)
    k = k_ref[0]                                   # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = (q_pos < seq_q) & (k_pos < seq_k)
    if kind == "causal":
        ok &= k_pos <= q_pos
    elif kind == "local":
        ok &= (k_pos <= q_pos) & (k_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, kind: str = "causal", window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """q: (BH, S, D); k/v: (BKV, T, D). GQA: BH = BKV * group."""
    BH, S, D = q.shape
    BKV, T, _ = k.shape
    group = BH // BKV
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq = -(-S // block_q)
    nk = -(-T // block_k)
    pad_q = nq * block_q - S
    pad_k = nk * block_k - T
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, kind=kind, window=window, block_q=block_q,
        block_k=block_k, n_kv_blocks=nk, seq_q=S, seq_k=T)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
