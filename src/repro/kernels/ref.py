"""Pure-jnp oracles for every Pallas kernel (the NCU-replay analogue:
deterministic reference semantics the kernels are validated against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_flash_attention(q, k, v, kind: str = "causal", window: int = 0):
    """q (BH,S,D); k/v (BKV,T,D); GQA group = BH // BKV."""
    BH, S, D = q.shape
    BKV, T, _ = k.shape
    g = BH // BKV
    qg = q.reshape(BKV, g, S, D)
    s = jnp.einsum("bgsd,btd->bgst", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    qp, kp = jnp.arange(S)[:, None], jnp.arange(T)[None, :]
    if kind == "causal":
        ok = kp <= qp
    elif kind == "local":
        ok = (kp <= qp) & (kp > qp - window)
    else:
        ok = jnp.ones((S, T), bool)
    s = jnp.where(ok, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgst,btd->bgsd", w.astype(v.dtype), v)
    return o.reshape(BH, S, D)


def ref_flash_decode(q, k, v, kv_len):
    """q (BKV,G,D); k/v (BKV,T,D); kv_len (BKV,)."""
    BKV, G, D = q.shape
    T = k.shape[1]
    s = jnp.einsum("bgd,btd->bgt", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    ok = jnp.arange(T)[None, None, :] < kv_len[:, None, None]
    s = jnp.where(ok, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgt,btd->bgd", w.astype(v.dtype), v)


def ref_ssm_scan(x, dt, A, B, C):
    """Sequential-oracle mamba1 scan. x/dt (Bb,S,di); A (di,N); B/C (Bb,S,N)."""
    Bb, S, di = x.shape
    N = A.shape[1]
    # f32 scan state by contract (matches models.ssm.mamba1_scan): pin
    # dt/A so f64 inputs under x64 don't promote the carry
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)

    def step(h, t):
        dA = jnp.exp(dt[:, t][..., None] * A)
        dBx = (dt[:, t] * x[:, t].astype(jnp.float32))[..., None] * B[:, t][:, None, :].astype(jnp.float32)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C[:, t].astype(jnp.float32))
        return h, y

    _, ys = jax.lax.scan(step, jnp.zeros((Bb, di, N), jnp.float32),
                         jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def ref_rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ------------------------- stressor oracles --------------------------- #
def ref_stress_mxu(a, b, iters: int):
    def body(_, c):
        c = jax.lax.dot_general(c, b, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m = jnp.max(jnp.abs(c), axis=(1, 2), keepdims=True)
        return c / jnp.maximum(m, 1.0)

    c = jax.lax.fori_loop(0, iters, body, a.astype(jnp.float32))
    return c.astype(a.dtype)


def ref_stress_vpu(x, iters: int, ilp: int):
    xf = x.astype(jnp.float32)
    accs = tuple(xf + i for i in range(ilp))

    def body(_, accs):
        return tuple(a * 1.000001 + 0.5 for a in accs)

    accs = jax.lax.fori_loop(0, iters, body, accs)
    out = accs[0]
    for a in accs[1:]:
        out = out + a
    return (out / (ilp * 4.0)).astype(x.dtype)


def ref_stress_hbm(x):
    return x


def ref_stress_vmem(x, iters: int, stride: int, block_rows: int = 512):
    R = x.shape[0]
    br = min(block_rows, R)

    def per_block(xb):
        def body(_, y):
            return y + jnp.roll(y, stride, 0)

        y = jax.lax.fori_loop(0, iters, body, xb.astype(jnp.float32))
        return (y / (2.0 ** iters)).astype(x.dtype)

    blocks = x.reshape(R // br, br, x.shape[1])
    return jax.vmap(per_block)(blocks).reshape(x.shape)
