"""TPU-native resource-stressor microbenchmark kernels — the paper's
custom CUDA benchmark suite (§4.1) adapted per DESIGN.md §2:

  stress_mxu    — repeated MXU matmuls on a VMEM-resident tile; tunable
                  `iters` = arithmetic intensity (paper's "compute kernel").
  stress_vpu    — independent element-wise FMA chains; tunable `ilp`
                  mirrors the paper's S1..S4 ILP sweep (issue/IPC stressor).
  stress_hbm    — streaming copy of a large array through VMEM (paper's
                  "copy kernel"; HBM-bandwidth stressor).
  stress_vmem   — strided VMEM load/store loop: sublane-strided rolls
                  serialize vector accesses (bank-conflict analogue).

Each returns a checkable value so the interpret-mode oracle tests in
tests/test_kernels.py can assert numerics, and each has an
analytic resource-demand vector in ``repro.core.sensitivity`` used by the
interference estimator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------- #
#  MXU stressor                                                          #
# --------------------------------------------------------------------- #
def _mxu_kernel(a_ref, b_ref, o_ref, *, iters: int):
    a = a_ref[0]
    b = b_ref[...]

    def body(_, c):
        c = jax.lax.dot(c, b, preferred_element_type=jnp.float32)
        return c / jnp.maximum(jnp.max(jnp.abs(c)), 1.0)   # keep bounded

    c = jax.lax.fori_loop(0, iters, body, a.astype(jnp.float32))
    o_ref[0] = c.astype(o_ref.dtype)


def stress_mxu(a, b, iters: int = 64, interpret: bool = False):
    """a: (n_tiles, T, T); b: (T, T). FLOPs = n_tiles * iters * 2*T^3."""
    n, T, _ = a.shape
    return pl.pallas_call(
        functools.partial(_mxu_kernel, iters=iters),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, T, T), lambda i: (i, 0, 0)),
                  pl.BlockSpec((T, T), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, T, T), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)


# --------------------------------------------------------------------- #
#  VPU / issue stressor (ILP sweep)                                      #
# --------------------------------------------------------------------- #
def _vpu_kernel(x_ref, o_ref, *, iters: int, ilp: int):
    x = x_ref[...].astype(jnp.float32)

    def body(_, accs):
        # `ilp` independent FMA chains — mirrors the paper's S1..S4
        return tuple(a * 1.000001 + 0.5 for a in accs)

    accs = tuple(x + i for i in range(ilp))
    accs = jax.lax.fori_loop(0, iters, body, accs)
    out = accs[0]
    for a in accs[1:]:
        out = out + a
    o_ref[...] = (out / (ilp * 4.0)).astype(o_ref.dtype)


def stress_vpu(x, iters: int = 256, ilp: int = 4, interpret: bool = False):
    """x: (R, 128·k). VPU-flops = R*cols*iters*ilp*2."""
    R, C = x.shape
    br = min(256, R)
    assert R % br == 0
    return pl.pallas_call(
        functools.partial(_vpu_kernel, iters=iters, ilp=ilp),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


# --------------------------------------------------------------------- #
#  HBM bandwidth stressor (streaming copy)                               #
# --------------------------------------------------------------------- #
def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def stress_hbm(x, block_rows: int = 1024, interpret: bool = False):
    """Pure streaming copy HBM->VMEM->HBM. bytes = 2 * x.nbytes."""
    R, C = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    return pl.pallas_call(
        _copy_kernel,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


# --------------------------------------------------------------------- #
#  VMEM strided-access stressor (bank-conflict analogue)                 #
# --------------------------------------------------------------------- #
def _vmem_kernel(x_ref, o_ref, *, iters: int, stride: int):
    x = x_ref[...]

    def body(_, y):
        # sublane-strided roll: stride 1 = conflict-free layout;
        # larger strides force cross-sublane shuffles every access.
        return y + jnp.roll(y, stride, 0)

    y = jax.lax.fori_loop(0, iters, body, x.astype(jnp.float32))
    o_ref[...] = (y / (2.0 ** iters)).astype(o_ref.dtype)


def stress_vmem(x, iters: int = 64, stride: int = 8, interpret: bool = False):
    """x: (R, 128·k). In-VMEM strided traffic = iters * 2 * block bytes."""
    R, C = x.shape
    br = min(512, R)
    assert R % br == 0
    return pl.pallas_call(
        functools.partial(_vmem_kernel, iters=iters, stride=stride),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
