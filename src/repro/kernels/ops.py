"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) every kernel runs in ``interpret=True`` mode — the
kernel body executes in Python with identical semantics; on TPU the same
call sites compile to Mosaic. ``repro.models.attention`` dispatches here
when ``attn_impl == "pallas"``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssm_scan as _ssm
from repro.kernels import stressors as _st


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("kind", "window", "softcap", "block_q", "block_k"))
def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128):
    """Model-layout wrapper: q (B,S,H,D); k/v (B,T,KVH,D) -> (B,S,H,D).
    (softcap unsupported in the kernel; asserted off.)"""
    assert not softcap, "softcap not implemented in the Pallas kernel"
    B, S, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, T, D)
    # group query heads of one kv head adjacently: (B, KVH, G, S, D)
    qf = q.reshape(B, S, KVH, H // KVH, D).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(B * H, S, D)
    o = _fa.flash_attention_bhsd(qf, kf, vf, kind=kind, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=_interpret())
    o = o.reshape(B, KVH, H // KVH, S, D).transpose(0, 3, 1, 2, 4)
    return o.reshape(B, S, H, D)


@partial(jax.jit, static_argnames=("block_k",))
def flash_decode(q, k, v, kv_len, *, block_k: int = 512):
    """q (B,1,H,D); k/v (B,T,KVH,D); kv_len () or (B,) -> (B,1,H,D)."""
    B, _, H, D = q.shape
    T, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qf = q.reshape(B, KVH, G, D).reshape(B * KVH, G, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KVH, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KVH, T, D)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
    lens = jnp.repeat(kv_len, KVH)
    o = _dec.flash_decode_bkgd(qf, kf, vf, lens, block_k=block_k,
                               interpret=_interpret())
    return o.reshape(B, KVH, G, D).reshape(B, 1, H, D)


@partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, eps: float = 1e-6, block_rows: int = 256):
    shape = x.shape
    out = _rms.rmsnorm_pallas(x.reshape(-1, shape[-1]), scale, eps=eps,
                              block_rows=block_rows, interpret=_interpret())
    return out.reshape(shape)


@partial(jax.jit, static_argnames=("chunk", "block_d"))
def ssm_scan(x, dt, A, B, C, *, chunk: int = 64, block_d: int = 512):
    return _ssm.ssm_scan_pallas(x, dt, A, B, C, chunk=chunk,
                                block_d=block_d, interpret=_interpret())


# stressors (used by the sensitivity harness + tests)
def mxu_stressor(a, b, iters=64):
    return _st.stress_mxu(a, b, iters=iters, interpret=_interpret())


def vpu_stressor(x, iters=256, ilp=4):
    return _st.stress_vpu(x, iters=iters, ilp=ilp, interpret=_interpret())


def hbm_stressor(x, block_rows=1024):
    return _st.stress_hbm(x, block_rows=block_rows, interpret=_interpret())


def vmem_stressor(x, iters=64, stride=8):
    return _st.stress_vmem(x, iters=iters, stride=stride,
                           interpret=_interpret())
