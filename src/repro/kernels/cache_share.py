"""Pallas kernel for the estimator's cache-share / thrash-cliff stage.

The batched interference solver's cache model (paper Fig. 3) assigns
every scenario member a shared-cache residency share:

  * a member colocated with any other cache user keeps its hits only
    while the COMBINED working set fits — one byte over capacity and
    interleaved streams evict each other before reuse (share -> 0);
  * a lone cache user keeps the proportional residency min(1, C / ws);
  * members with no working set are unaffected (share 1).

This file provides that stage as a row-blocked Pallas TPU kernel
(`cache_share_pallas`) so the jax solver backend keeps the whole
pricing pipeline on-chip when it actually runs on a TPU.  Platform
detection lives in `repro.core.estimator_jax` — on CPU/GPU the jnp
fallback (`repro.core.estimator_jax.cache_share_ref`) computes the
identical expression, and tests pin kernel == fallback in interpret
mode at exact equality.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128      # TPU lane width: the member axis is padded up to it


def _kernel(ws_ref, pres_ref, cap_ref, out_ref):
    ws = ws_ref[...]                       # (br, Kp)
    pres = pres_ref[...]                   # (br, Kp) 0/1 in ws dtype
    cap = cap_ref[0]
    total_ws = ws.sum(axis=-1, keepdims=True)      # padded columns are 0
    resident_col = jnp.where(total_ws > cap, 0.0, 1.0)
    nk = pres.sum(axis=-1, keepdims=True)
    has_ws = ws > 0
    out_ref[...] = jnp.where(
        has_ws & (nk > 1), resident_col,
        jnp.where(has_ws, jnp.minimum(1.0, cap / jnp.maximum(ws, 1.0)),
                  1.0))


def cache_share_pallas(ws, present, cache_cap, block_rows: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """Cache share per scenario member: ws/present are (S, K) with
    exclusion-zeroed working sets; returns (S, K) in ws.dtype.  K is
    padded to the 128-wide lane dim and rows to `block_rows`, so the
    row reductions see only zeroed padding."""
    S, K = ws.shape
    pres = present.astype(ws.dtype)
    kp = (-K) % _LANES
    block_rows = min(block_rows, max(S, 1))
    rp = (-S) % block_rows
    if kp or rp:
        ws = jnp.pad(ws, ((0, rp), (0, kp)))
        pres = jnp.pad(pres, ((0, rp), (0, kp)))
    cap = jnp.reshape(jnp.asarray(cache_cap, ws.dtype), (1,))
    n = (S + rp) // block_rows
    out = pl.pallas_call(
        _kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, K + kp), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, K + kp), lambda i: (i, 0)),
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, K + kp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S + rp, K + kp), ws.dtype),
        interpret=interpret,
    )(ws, pres, cap)
    return out[:S, :K]
