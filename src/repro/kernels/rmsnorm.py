"""Pallas TPU fused RMSNorm kernel (rows blocked into VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                # (br, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-6, block_rows: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (R, d) (flatten leading dims first); scale: (d,)."""
    R, d = x.shape
    block_rows = min(block_rows, R)
    pad = (-R) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n = (R + pad) // block_rows
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pad, d), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:R]
