"""Pallas TPU selective-scan (Mamba-1) kernel.

TPU adaptation of the fused CUDA selective scan: the expanded state
h (bd, N) stays resident in VMEM scratch across sequence chunks (the grid's
sequential minor axis), while x/dt/B/C stream HBM->VMEM chunk by chunk.
This avoids ever materializing the (S, d_inner, N) tensor in HBM — the
exact analogue of keeping h in registers/SMEM on GPU.

Grid: (B, n_d_blocks, n_chunks); chunks sequential.
Blocks: x/dt (1, chunk, bd); B/C (1, chunk, N); y (1, chunk, bd);
scratch h (bd, N) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
            chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...]                                    # (bd, N) f32
    x = x_ref[0].astype(jnp.float32)                  # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)                # (chunk, bd)
    Bm = b_ref[0].astype(jnp.float32)                 # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)                 # (chunk, N)

    def step(t, carry):
        h, ys = carry
        dA = jnp.exp(dt[t][:, None] * A)              # (bd, N)
        dBx = (dt[t] * x[t])[:, None] * Bm[t][None, :]
        h = dA * h + dBx
        y_t = jnp.sum(h * Cm[t][None, :], axis=1)     # (bd,)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y_t[None, :], t, 0)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_ref[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def ssm_scan_pallas(x, dt, A, B, C, *, chunk: int = 64, block_d: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """x, dt: (Bb, S, di); A: (di, N) (negative reals); B, C: (Bb, S, N).
    Returns y (Bb, S, di) f32-accumulated, cast to x.dtype."""
    Bb, S, di = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    block_d = min(block_d, di)
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    assert di % block_d == 0
    nc = S // chunk
    nd = di // block_d
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(Bb, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C)
