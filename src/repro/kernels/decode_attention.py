"""Pallas TPU flash-decode kernel: one query token vs. a long KV cache.

Layout: q (B, KVH, G, D) — all query heads of one kv group together so the
(G, bk) score tile feeds the MXU; k/v (B*KVH, T, D). The KV-length grid
axis is sequential with m/l/acc scratch carry (flash-decode partials).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_k: int, n_kv_blocks: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                       # (G, D)
    k = k_ref[0]                                       # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_len = len_ref[0]
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_bkgd(q, k, v, kv_len, *, block_k: int = 512,
                      interpret: bool = False) -> jnp.ndarray:
    """q: (BKV, G, D) one token per sequence; k/v: (BKV, T, D);
    kv_len: (BKV,) int32 valid lengths. Returns (BKV, G, D)."""
    BKV, G, D = q.shape
    T = k.shape[1]
    block_k = min(block_k, T)
    nk = -(-T // block_k)
    pad = nk * block_k - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    kernel = functools.partial(_kernel, scale=1.0 / math.sqrt(D),
                               block_k=block_k, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(BKV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ik: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)
