"""repro.calib: the measure -> fit -> validate -> drift loop.

Property-tests the synthetic round-trip (seeded random perturbations of
diverse ground-truth profiles must be recovered to <=5% held-out mix
error), the drift monitor's flag/refit mechanics, and the sim
integration (injected mid-trace shift -> flagged + re-fit; clean
same-seed twin -> zero flags; bit-identical reports).
"""
import math
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))

from repro.calib import (CACHE_WS_FRACTIONS, FIT_LAMBDAS, Colocation,
                         DriftConfig, DriftMonitor, FitConfig,
                         MeasurementSet, StressorSpec, SyntheticBackend,
                         colocation_scenario, fit_profiles, holdout_mixes,
                         median_iqr_time, perturb_profile,
                         predict_slowdowns, profile_to_params,
                         scale_workload, sweep_colocations, validate)
from repro.core.estimator import solve_scenarios
from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import RESOURCE_AXES, TPU_V5E, TPU_V5P
from repro.core.scenario import Scenario
from repro.core.sensitivity import stressor
from repro.sim import SimConfig, Simulator, TraceConfig, generate_trace

import bench_calib

DEV = TPU_V5E


# ------------------------------------------------------------------ #
#  satellite: the stressor() builder occupies exactly lambda           #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dev", [TPU_V5E, TPU_V5P],
                         ids=["v5e", "v5p"])
@pytest.mark.parametrize("lam", [0.1, 0.5, 0.9])
def test_stressor_occupies_lambda_on_axis(dev, lam):
    for axis in RESOURCE_AXES:
        st = stressor(axis, lam, dev)
        u = st.utilization(dev)
        assert u[axis] == pytest.approx(lam, rel=1e-9)
        for other in RESOURCE_AXES:
            if other != axis:
                assert u[other] == 0.0
        # duration-bound by construction: occupies lam, not saturated
        assert st.isolated_time(dev) == pytest.approx(1.0)


# ------------------------------------------------------------------ #
#  measurement sweep structure                                        #
# ------------------------------------------------------------------ #
def test_sweep_covers_axes_probe_kinds_and_cache():
    cols = sweep_colocations(["a", "b"], DEV)
    for v in ("a", "b"):
        mine = [c for c in cols if c.victim == v]
        axes = {c.single_axis for c in mine if c.single_axis}
        assert axes == set(RESOURCE_AXES)
        assert any(c.observe == "stressor" for c in mine)
        assert any(len(c.stressors) > 1 for c in mine)
        ws = sorted(c.stressors[0].working_set
                    for c in mine if c.is_cache_probe)
        assert ws == sorted(f * DEV.cache_capacity
                            for f in CACHE_WS_FRACTIONS)


def test_colocation_scenario_reverse_probe_observes_stressor():
    k = KernelProfile("k", demand={"hbm": 0.5 * DEV.capacity("hbm")},
                      duration=1.0)
    c = Colocation("k", (StressorSpec("hbm", 0.9),), observe="stressor")
    sc = colocation_scenario(c, k, DEV, {})
    assert sc.victims[0].name.startswith("stress:hbm")
    assert k in sc.background
    with pytest.raises(ValueError):
        colocation_scenario(Colocation("k", (), observe="stressor"),
                            k, DEV, {})


def test_reverse_probe_reveals_sub_fair_share_demand():
    # u=0.3 victim vs a single lam=0.9 stressor: the victim is never
    # throttled (fair share 0.5 > 0.3) but the stressor IS - the whole
    # reason the sweep measures both sides (mxu: no queueing inflation,
    # so the max-min algebra is exact)
    u = 0.3
    k = KernelProfile("k", demand={"mxu": u * DEV.capacity("mxu")},
                      duration=1.0)
    fwd = colocation_scenario(
        Colocation("k", (StressorSpec("mxu", 0.9),)), k, DEV, {})
    rev = colocation_scenario(
        Colocation("k", (StressorSpec("mxu", 0.9),), observe="stressor"),
        k, DEV, {})
    s_fwd, s_rev = solve_scenarios([fwd, rev], DEV).slowdowns[:, 0]
    assert s_fwd == pytest.approx(1.0)
    assert s_rev == pytest.approx(0.9 / (1.0 - u), rel=1e-6)


# ------------------------------------------------------------------ #
#  synthetic backend                                                  #
# ------------------------------------------------------------------ #
def _truth(dev=DEV, seed=7, names=("decode", "gemm", "attn")):
    rng = np.random.default_rng(seed)
    base = bench_calib.base_kernels(dev)
    return {n: perturb_profile(base[n], rng, scale=0.25, dev=dev)
            for n in names}


def test_synthetic_backend_same_seed_bit_identical():
    truth = _truth()
    a = SyntheticBackend(truth, DEV, noise=0.02, seed=5).run_sweep(
        sorted(truth))
    b = SyntheticBackend(truth, DEV, noise=0.02, seed=5).run_sweep(
        sorted(truth))
    assert np.array_equal(a.slowdowns, b.slowdowns)
    assert a.isolated_times == b.isolated_times
    c = SyntheticBackend(truth, DEV, noise=0.02, seed=6).run_sweep(
        sorted(truth))
    assert not np.array_equal(a.slowdowns, c.slowdowns)


def test_synthetic_backend_hides_truth_but_serves_it():
    truth = _truth(names=("decode",))
    be = SyntheticBackend(truth, DEV)
    cols = [Colocation("decode", (StressorSpec("hbm", 0.9),))]
    expect = solve_scenarios(
        [colocation_scenario(cols[0], truth["decode"], DEV, truth)],
        DEV).slowdowns[0, 0]
    assert be.measure(cols)[0] == pytest.approx(float(expect))
    assert be.isolated_time("decode") == pytest.approx(
        truth["decode"].isolated_time(DEV))


# ------------------------------------------------------------------ #
#  round-trip fit (the tentpole property)                             #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_roundtrip_recovers_heldout_mixes_within_5pct(seed):
    # random perturbation -> sweep -> fit -> score on mixes the fitter
    # never saw; the bench gates one seed, this property-checks more
    truth = _truth(seed=seed)
    be = SyntheticBackend(truth, DEV, seed=seed)
    fitted = fit_profiles(be.run_sweep(sorted(truth)))
    rep = validate(fitted, be,
                   holdout_mixes(sorted(truth),
                                 np.random.default_rng(seed + 100)))
    assert rep.max_rel_error <= 0.05, rep.worst_mix


def test_roundtrip_recovers_axis_demands_and_cache_knobs():
    truth = _truth(seed=7)
    be = SyntheticBackend(truth, DEV, seed=7)
    fitted = fit_profiles(be.run_sweep(sorted(truth)))
    for name, true_k in truth.items():
        got = profile_to_params(fitted[name], DEV)
        want = profile_to_params(true_k, DEV)
        for axis in RESOURCE_AXES:
            # reverse probes resolve u > 0.02; below that the demand is
            # unobservable under max-min and may fit as ~0
            if want[f"u:{axis}"] > 0.05:
                assert got[f"u:{axis}"] == pytest.approx(
                    want[f"u:{axis}"], abs=0.03), (name, axis)
        if want["ws"] > 0:
            assert got["ws"] == pytest.approx(want["ws"], rel=0.5)
            assert got["hit"] == pytest.approx(want["hit"], abs=0.15)
        assert fitted[name].isolated_time(DEV) == pytest.approx(
            true_k.isolated_time(DEV))


def test_roundtrip_survives_measurement_noise():
    truth = _truth(seed=3)
    be = SyntheticBackend(truth, DEV, noise=0.01, seed=3)
    fitted = fit_profiles(be.run_sweep(sorted(truth)))
    clean = SyntheticBackend(truth, DEV, seed=3)   # score against truth
    rep = validate(fitted, clean,
                   holdout_mixes(sorted(truth),
                                 np.random.default_rng(103)))
    assert rep.max_rel_error <= 0.15


def test_perturb_profile_seeded_and_feasible():
    base = bench_calib.base_kernels(DEV)["decode"]
    a = perturb_profile(base, np.random.default_rng(9), dev=DEV)
    b = perturb_profile(base, np.random.default_rng(9), dev=DEV)
    assert a.demand == b.demand and a.duration == b.duration
    for _ in range(20):
        p = perturb_profile(base, np.random.default_rng(_), scale=0.6,
                            dev=DEV)
        assert all(u <= 1.0 + 1e-9 for u in p.utilization(DEV).values())


def test_predict_slowdowns_matches_backend_on_truth():
    # the fitter's forward model and the backend share one lowering:
    # predicting with the TRUE profiles reproduces the measurements
    truth = _truth(seed=11)
    be = SyntheticBackend(truth, DEV, seed=11)
    cols = sweep_colocations(sorted(truth), DEV)
    np.testing.assert_allclose(predict_slowdowns(truth, cols, DEV),
                               be.measure(cols), rtol=1e-9)


# ------------------------------------------------------------------ #
#  drift monitor                                                      #
# ------------------------------------------------------------------ #
def test_monitor_flags_after_warmup_only():
    mon = DriftMonitor(DriftConfig(warmup=4, threshold=0.15))
    newly = [mon.observe("w", 1.0, 1.5) for _ in range(6)]
    assert newly.index(True) == 3            # obs #4 = first eligible
    assert sum(newly) == 1                   # flag fires once
    assert mon.is_flagged("w") and mon.flags == 1
    assert mon.divergence("w") > 0.15


def test_monitor_silent_on_agreement_and_small_noise():
    mon = DriftMonitor(DriftConfig(warmup=3, threshold=0.15))
    rng = np.random.default_rng(0)
    for _ in range(50):
        assert not mon.observe("w", 2.0, 2.0)
        assert not mon.observe("v", 1.5,
                               1.5 * math.exp(0.01 * rng.standard_normal()))
    assert mon.flagged == [] and mon.flags == 0


def test_monitor_forget_drops_state():
    mon = DriftMonitor(DriftConfig(warmup=1))
    mon.observe("w", 1.0, 2.0)
    assert mon.is_flagged("w")
    mon.forget("w")
    assert not mon.is_flagged("w") and mon.flagged == []
    assert mon.flags == 1                    # history of flag events stays


def _drift_pair(scale=1.7):
    """Believed vs true (scaled) single-kernel roofline-bound workload
    plus a contending background - the regime where a demand-scale
    shift is observable (duration-bound workloads hide it)."""
    dev = TPU_V5P
    k = KernelProfile("k", demand={"hbm": 0.5 * dev.capacity("hbm")},
                      duration=0.5)
    believed = WorkloadProfile("w", kernels=(k,))
    true = scale_workload(believed, scale)
    background = (stressor("hbm", 0.9, dev),)
    return dev, believed, true, background


def _fold(w, background, believed, dev):
    s = solve_scenarios([Scenario((w.kernels[0],), background)],
                        dev).slowdowns[0, 0]
    return float(s) * w.total_time(dev) / believed.total_time(dev)


def test_monitor_refit_recovers_demand_scale():
    dev, believed, true, bg = _drift_pair(scale=1.7)
    mon = DriftMonitor(DriftConfig(warmup=3))
    pred = _fold(believed, bg, believed, dev)
    obs = _fold(true, bg, believed, dev)
    assert obs > pred                        # shift is observable here
    flagged = [mon.observe("w", pred, obs, bg, None, dev)
               for _ in range(5)]
    assert any(flagged)
    refit = mon.refit("w", believed)
    got = (refit.kernels[0].demand["hbm"]
           / believed.kernels[0].demand["hbm"])
    assert got == pytest.approx(1.7, rel=0.1)
    assert not mon.is_flagged("w")           # refit resets the state
    assert mon.refits == 1
    # corrected profile predicts the observations it was fitted from
    assert _fold(refit, bg, believed, dev) == pytest.approx(obs, rel=0.05)


def test_monitor_refit_budget_and_empty_cases():
    dev, believed, true, bg = _drift_pair()
    mon = DriftMonitor(DriftConfig(warmup=1, max_refits=1))
    assert not mon.can_refit("unseen")
    assert mon.refit("unseen", believed) is None
    mon.observe("w", 1.0, 2.0)               # no device -> no samples
    assert not mon.can_refit("w")
    obs = _fold(true, bg, believed, dev)
    mon.observe("w", 1.0, obs, bg, None, dev)
    assert mon.can_refit("w")
    assert mon.refit("w", believed) is not None
    mon.observe("w", 1.0, obs, bg, None, dev)
    assert not mon.can_refit("w")            # budget spent
    assert mon.refit("w", believed) is None


def test_scale_workload_scales_demands_only():
    _, believed, _, _ = _drift_pair()
    s = scale_workload(believed, 2.0)
    assert s.kernels[0].demand["hbm"] == pytest.approx(
        2.0 * believed.kernels[0].demand["hbm"])
    assert s.kernels[0].duration == believed.kernels[0].duration
    assert s.name == believed.name


# ------------------------------------------------------------------ #
#  sim integration (the bench_calib drift gate, property form)         #
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def clean_run():
    sim = Simulator(generate_trace(TraceConfig(**bench_calib.DRIFT_TRACE)),
                    bench_calib.drift_devices())
    return sim.run()


@pytest.fixture(scope="module")
def shift_target():
    return bench_calib.pick_shift_target()


@pytest.fixture(scope="module")
def shifted_run(shift_target):
    return bench_calib.run_drift(*shift_target)


def test_sim_clean_trace_zero_flags(clean_run):
    calib = clean_run["calib"]
    assert calib["observations"] > 0
    assert calib["flags"] == 0 and calib["refits"] == 0
    assert calib["flagged_tenants"] == []


def test_sim_shift_flags_and_refits_exactly_the_tenant(
        shift_target, shifted_run):
    tenant, _ = shift_target
    calib = shifted_run["calib"]
    assert calib["flags"] >= 1 and calib["refits"] >= 1
    assert calib["flagged_tenants"] == [tenant]
    assert shifted_run["fleet"]["event_loop_errors"] == 0


def test_sim_shifted_report_bit_identical(shift_target, shifted_run):
    assert bench_calib.run_drift(*shift_target) == shifted_run


def test_sim_calibration_can_be_disabled(shift_target):
    tenant, scale = shift_target
    cfg = TraceConfig(**bench_calib.DRIFT_TRACE,
                      profile_shifts=((bench_calib.SHIFT_T, tenant,
                                       scale),))
    sim = Simulator(generate_trace(cfg), bench_calib.drift_devices(),
                    sim_config=SimConfig(calibrate=False))
    report = sim.run()
    assert sim.fleet.calib is None
    assert report["calib"] == {"observations": 0, "flags": 0,
                               "refits": 0, "flagged_tenants": []}


def test_sim_shift_unknown_tenant_raises():
    cfg = TraceConfig(**bench_calib.DRIFT_TRACE,
                      profile_shifts=((5.0, "nope", 2.0),))
    sim = Simulator(generate_trace(cfg), bench_calib.drift_devices())
    with pytest.raises(KeyError):
        sim.run()


# ------------------------------------------------------------------ #
#  timers / pallas backend smoke                                      #
# ------------------------------------------------------------------ #
def test_median_iqr_time_sanity():
    calls = []
    med, iqr = median_iqr_time(lambda: calls.append(1), repeats=5,
                               warmup=2)
    assert len(calls) == 7
    assert med > 0.0 and iqr >= 0.0


def test_pallas_backend_interpret_smoke():
    import jax
    import jax.numpy as jnp

    from repro.calib import PallasBackend

    x = jnp.ones((64, 64), jnp.float32)
    victim = jax.jit(lambda: (x @ x).sum())
    be = PallasBackend({"v": victim}, DEV, repeats=2, interpret=True)
    assert be.isolated_time("v") > 0.0
    cols = [Colocation("v", (StressorSpec("vpu", 0.2),)),
            Colocation("v", (StressorSpec("vpu", 0.2),),
                       observe="stressor")]
    slows = be.measure(cols)
    assert slows.shape == (2,) and np.all(slows >= 1.0)
    with pytest.raises(NotImplementedError):
        be.measure([Colocation("v", cohort=("v",))])
