"""Serving engine: output parity vs. naive full-forward generation, HOL
mitigation via chunked prefill, slot allocation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, tiny_config
from repro.models import build_model
from repro.serve import Engine, EngineConfig, SlotAllocator
from repro.serve.kvcache import Sequence

CFG = tiny_config(get_config("qwen3-1.7b")).with_overrides(attn_impl="reference")


def greedy_reference(cfg, params, prompt, max_new):
    """Ground truth: re-run the FULL forward for every generated token."""
    model = build_model(cfg)
    toks = list(prompt)
    for _ in range(max_new):
        logits, _ = model.forward(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(np.argmax(np.asarray(logits)[0, -1])))
    return toks[len(prompt):]


@pytest.mark.parametrize("mode", ["serial", "interference_aware"])
def test_engine_matches_full_forward(mode):
    eng = Engine(CFG, ecfg=EngineConfig(max_slots=2, max_len=96,
                                        prefill_chunk=16, mode=mode))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, CFG.vocab_size, size=n).tolist()
               for n in (9, 23)]
    ids = [eng.submit(p, max_new=4) for p in prompts]
    metrics = eng.run_until_done()
    for i, p in zip(ids, prompts):
        want = greedy_reference(CFG, eng.params, p, 4)
        assert metrics[i]["output"] == want, (mode, i)


def test_engine_continuous_batching_over_subscription():
    """More requests than slots: all must finish via slot recycling."""
    eng = Engine(CFG, ecfg=EngineConfig(max_slots=2, max_len=64,
                                        prefill_chunk=16))
    rng = np.random.default_rng(1)
    ids = [eng.submit(rng.integers(1, 50, size=8).tolist(), max_new=3)
           for _ in range(5)]
    m = eng.run_until_done()
    assert sorted(m) == sorted(ids)
    assert all(v["new_tokens"] == 3 for v in m.values())


def test_chunked_prefill_reduces_decode_gap():
    """Paper §4.2: a long prompt must not block the decode batch — the
    interference-aware mode splits it into chunks, so the number of
    decode steps interleaved during the long prefill is > 0."""
    def interleavings(mode):
        eng = Engine(CFG, ecfg=EngineConfig(max_slots=2, max_len=320,
                                            prefill_chunk=32, mode=mode,
                                            tbt_slo_ms=1e-6))
        eng.submit([1, 2, 3, 4], max_new=40)     # decoder workload
        for _ in range(4):                        # let it start decoding
            eng.step()
        eng.submit(list(range(1, 257)), max_new=2)  # long prompt arrives
        kinds = []
        for _ in range(40):
            n0 = len(eng.events)
            eng.step()
            kinds += [e.kind for e in eng.events[n0:]]
        # count decodes between first and last prefill chunk
        first = kinds.index("prefill_chunk") if "prefill_chunk" in kinds else 0
        last = len(kinds) - 1 - kinds[::-1].index("prefill_chunk") \
            if "prefill_chunk" in kinds else 0
        return kinds[first:last].count("decode"), kinds.count("prefill_chunk")

    serial_interleave, serial_chunks = interleavings("serial")
    aware_interleave, aware_chunks = interleavings("interference_aware")
    assert serial_chunks == 1                    # monolithic prefill
    assert aware_chunks > 1                      # chunked
    assert aware_interleave > serial_interleave  # decode kept flowing


def test_pick_chunk_prices_floor_chunk(monkeypatch):
    """The halving ladder must include the 16-token floor as a PRICED
    candidate (the old loop stopped above it), and the no-candidate-
    passes fallback must be estimator-backed: the priced candidate with
    the lowest predicted TBT, not an unpriced halving."""
    import repro.serve.engine as engine_mod

    eng = Engine(CFG, ecfg=EngineConfig(max_slots=2, max_len=96,
                                        prefill_chunk=64,
                                        tbt_slo_ms=1e-9))   # nothing passes
    priced_chunks = []
    real_solve = engine_mod.solve_scenarios

    def spy(scenarios, dev=None):
        priced_chunks.append(
            [int(sc.background[0].name.removeprefix("prefill"))
             for sc in scenarios])
        return real_solve(scenarios, dev)

    monkeypatch.setattr(engine_mod, "solve_scenarios", spy)
    seq = Sequence(0, prompt_len=80, max_new=1)
    chunk = eng._pick_chunk(seq, n_active_decodes=1)
    assert priced_chunks and priced_chunks[-1] == [64, 32, 16]
    # the estimator-backed fallback: with TBT monotone in chunk size the
    # minimum predicted TBT is the floor chunk — and it was priced
    assert chunk == 16

    # with a sane SLO the largest passing candidate wins as before
    eng.ecfg.tbt_slo_ms = 1e9
    assert eng._pick_chunk(seq, n_active_decodes=1) == 64


def test_pick_chunk_short_remainder_still_priced(monkeypatch):
    """Prompts shorter than twice the floor used to skip pricing
    entirely (empty candidate ladder); now the floor chunk is priced."""
    import repro.serve.engine as engine_mod

    eng = Engine(CFG, ecfg=EngineConfig(max_slots=2, max_len=96,
                                        prefill_chunk=64))
    priced = []
    real_solve = engine_mod.solve_scenarios

    def spy(scenarios, dev=None):
        priced.append(
            [int(sc.background[0].name.removeprefix("prefill"))
             for sc in scenarios])
        return real_solve(scenarios, dev)

    monkeypatch.setattr(engine_mod, "solve_scenarios", spy)
    seq = Sequence(0, prompt_len=20, max_new=1)
    chunk = eng._pick_chunk(seq, n_active_decodes=1)
    assert priced == [[20, 16]]  # the floor chunk was estimator-priced
    assert chunk in (20, 16)


def test_slot_allocator():
    a = SlotAllocator(n_slots=2, max_len=32)
    s1 = Sequence(1, prompt_len=8, max_new=4)
    s2 = Sequence(2, prompt_len=8, max_new=4)
    s3 = Sequence(3, prompt_len=8, max_new=4)
    huge = Sequence(4, prompt_len=40, max_new=4)
    assert a.can_admit(s1) and a.admit(s1) in (0, 1)
    assert a.can_admit(s2)
    a.admit(s2)
    assert not a.can_admit(s3)          # full
    assert not a.can_admit(huge)        # never fits
    a.release(1)
    assert a.can_admit(s3)


def test_slot_allocator_admit_when_full_raises():
    a = SlotAllocator(n_slots=1, max_len=32)
    a.admit(Sequence(1, prompt_len=8, max_new=4))
    with pytest.raises(RuntimeError):
        a.admit(Sequence(2, prompt_len=8, max_new=4))
    # the failed admit must not leak state
    assert a.utilization == 1.0 and list(a.active) == [1]


def test_slot_allocator_double_release_raises():
    a = SlotAllocator(n_slots=2, max_len=32)
    a.admit(Sequence(1, prompt_len=8, max_new=4))
    a.release(1)
    with pytest.raises(KeyError):
        a.release(1)
    with pytest.raises(KeyError):
        a.release(99)                       # never admitted
    # free list must not grow from failed releases
    assert len(a.free) == 2 and a.utilization == 0.0


def test_slot_allocator_can_admit_respects_max_len():
    a = SlotAllocator(n_slots=4, max_len=16)
    assert a.can_admit(Sequence(1, prompt_len=8, max_new=8))    # == max_len
    assert not a.can_admit(Sequence(2, prompt_len=8, max_new=9))  # one over
    with pytest.raises(RuntimeError):
        a.admit(Sequence(3, prompt_len=20, max_new=0))


def test_slot_allocator_utilization_round_trip():
    a = SlotAllocator(n_slots=4, max_len=32)
    seqs = [Sequence(i, prompt_len=4, max_new=4) for i in range(3)]
    slots = [a.admit(s) for s in seqs]
    assert len(set(slots)) == 3
    assert a.utilization == pytest.approx(0.75)
    assert a.active_slots().tolist() == sorted(slots)
    a.release(1)
    assert a.utilization == pytest.approx(0.5)
    assert a.active_slots().tolist() == sorted(s for i, s in
                                               zip(range(3), slots) if i != 1)
    a.release(0)
    a.release(2)
    assert a.utilization == 0.0 and a.active_slots().tolist() == []


def test_pick_chunk_degraded_mode_is_conservative():
    """Fleet hook: in degraded mode (device oversubscribed after a fleet
    failure) the scheduler must stop taking the largest passing chunk
    and always pick the minimum-predicted-TBT candidate; with TBT
    monotone in chunk size that is the floor chunk. The idle-batch 4x
    chunk boost is also disabled."""
    eng = Engine(CFG, ecfg=EngineConfig(max_slots=2, max_len=96,
                                        prefill_chunk=64,
                                        tbt_slo_ms=1e9))   # everything passes
    seq = Sequence(0, prompt_len=80, max_new=1)
    assert eng._pick_chunk(seq, n_active_decodes=1) == 64
    assert eng._pick_chunk(seq, n_active_decodes=0) == 80

    eng.set_degraded(True, reason="fleet: dev oversubscribed")
    assert eng._pick_chunk(seq, n_active_decodes=1) == 16
    assert eng._pick_chunk(seq, n_active_decodes=0) == 64  # no 4x boost
    assert eng.events[-1].kind == "degraded"

    eng.set_degraded(False)
    eng.set_degraded(False)            # idempotent: no duplicate event
    assert eng._pick_chunk(seq, n_active_decodes=1) == 64
    assert [e.kind for e in eng.events[-2:]] == ["degraded", "recovered"]
