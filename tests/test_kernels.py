"""Per-kernel interpret-mode validation: shape/dtype sweeps vs. the pure
jnp oracles in repro.kernels.ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.decode_attention import flash_decode_bkgd
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas
from repro.kernels import stressors

K = jax.random.PRNGKey


def _allclose(a, b, **kw):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **kw)


# --------------------------- flash attention -------------------------- #
@pytest.mark.parametrize("S,T,D,g,kind,dtype", [
    (128, 128, 64, 1, "causal", jnp.float32),
    (256, 256, 128, 4, "causal", jnp.bfloat16),
    (128, 384, 64, 2, "bidirectional", jnp.float32),
    (200, 200, 64, 2, "causal", jnp.float32),        # non-multiple of block
    (256, 256, 64, 1, "local", jnp.float32),
])
def test_flash_attention(S, T, D, g, kind, dtype):
    BKV = 2
    q = jax.random.normal(K(0), (BKV * g, S, D), dtype)
    k = jax.random.normal(K(1), (BKV, T, D), dtype)
    v = jax.random.normal(K(2), (BKV, T, D), dtype)
    out = flash_attention_bhsd(q, k, v, kind=kind, window=64,
                               block_q=128, block_k=128, interpret=True)
    want = ref.ref_flash_attention(q, k, v, kind=kind, window=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    _allclose(out, want, rtol=tol, atol=tol)


def test_flash_attention_model_layout():
    B, S, H, KVH, D = 2, 128, 8, 2, 64
    q = jax.random.normal(K(0), (B, S, H, D), jnp.float32)
    k = jax.random.normal(K(1), (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(K(2), (B, S, KVH, D), jnp.float32)
    from repro.models.attention import reference_attention
    out = ops.flash_attention(q, k, v, kind="causal")
    want = reference_attention(q, k, v, "causal")
    _allclose(out, want, rtol=2e-5, atol=2e-5)


# ---------------------------- flash decode ---------------------------- #
@pytest.mark.parametrize("T,G,D,block_k", [(512, 4, 64, 128),
                                           (384, 1, 128, 256),
                                           (1024, 8, 64, 512)])
def test_flash_decode(T, G, D, block_k):
    BKV = 3
    q = jax.random.normal(K(0), (BKV, G, D), jnp.float32)
    k = jax.random.normal(K(1), (BKV, T, D), jnp.float32)
    v = jax.random.normal(K(2), (BKV, T, D), jnp.float32)
    lens = jnp.array([T, T // 2, 7], jnp.int32)
    out = flash_decode_bkgd(q, k, v, lens, block_k=block_k, interpret=True)
    want = ref.ref_flash_decode(q, k, v, lens)
    _allclose(out, want, rtol=2e-5, atol=2e-5)


def test_flash_decode_vs_model_decode_attention():
    from repro.models.attention import decode_attention
    B, H, KVH, D, T = 2, 8, 2, 64, 256
    q = jax.random.normal(K(0), (B, 1, H, D), jnp.float32)
    k = jax.random.normal(K(1), (B, T, KVH, D), jnp.float32)
    v = jax.random.normal(K(2), (B, T, KVH, D), jnp.float32)
    lens = jnp.array([200, 64], jnp.int32)
    out = ops.flash_decode(q, k, v, lens)
    want = decode_attention(q, k, v, lens)
    _allclose(out, want, rtol=2e-5, atol=2e-5)


# ------------------------------ rmsnorm ------------------------------- #
@pytest.mark.parametrize("R,d,dtype", [(64, 256, jnp.float32),
                                       (100, 512, jnp.bfloat16),
                                       (1024, 128, jnp.float32)])
def test_rmsnorm(R, d, dtype):
    x = jax.random.normal(K(0), (R, d), dtype)
    s = jax.random.normal(K(1), (d,), jnp.float32)
    out = rmsnorm_pallas(x, s, interpret=True)
    want = ref.ref_rmsnorm(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    _allclose(out, want, rtol=tol, atol=tol)


# ------------------------------ ssm scan ------------------------------ #
@pytest.mark.parametrize("S,di,N,chunk,block_d", [
    (128, 64, 8, 32, 32), (64, 128, 16, 64, 128), (96, 32, 4, 16, 32)])
def test_ssm_scan(S, di, N, chunk, block_d):
    Bb = 2
    x = jax.random.normal(K(0), (Bb, S, di), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(K(1), (Bb, S, di), jnp.float32) - 2)
    A = -jnp.exp(jax.random.normal(K(2), (di, N), jnp.float32) * 0.3)
    B = jax.random.normal(K(3), (Bb, S, N), jnp.float32) * 0.5
    C = jax.random.normal(K(4), (Bb, S, N), jnp.float32) * 0.5
    out = ssm_scan_pallas(x, dt, A, B, C, chunk=chunk, block_d=block_d,
                          interpret=True)
    want = ref.ref_ssm_scan(x, dt, A, B, C)
    _allclose(out, want, rtol=1e-4, atol=1e-4)


def test_ssm_scan_matches_model_chunked_scan():
    """Pallas kernel == the model's chunked associative scan == oracle."""
    from repro.models.ssm import mamba1_scan
    Bb, S, di, N = 1, 64, 32, 8
    x = jax.random.normal(K(0), (Bb, S, di), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(K(1), (Bb, S, di)) - 2)
    A = -jnp.exp(jax.random.normal(K(2), (di, N)) * 0.3)
    B = jax.random.normal(K(3), (Bb, S, N)) * 0.5
    C = jax.random.normal(K(4), (Bb, S, N)) * 0.5
    y_model, _ = mamba1_scan(x, dt, A, B, C, chunk=16)
    y_oracle = ref.ref_ssm_scan(x, dt, A, B, C)
    _allclose(y_model, y_oracle, rtol=1e-4, atol=1e-4)


# ------------------------------ stressors ----------------------------- #
def test_stress_mxu():
    a = jax.random.normal(K(0), (2, 128, 128), jnp.float32)
    b = jax.random.normal(K(1), (128, 128), jnp.float32) * 0.1
    out = stressors.stress_mxu(a, b, iters=4, interpret=True)
    want = ref.ref_stress_mxu(a, b, iters=4)
    _allclose(out, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("ilp", [1, 2, 4])
def test_stress_vpu(ilp):
    x = jax.random.normal(K(0), (256, 128), jnp.float32)
    out = stressors.stress_vpu(x, iters=16, ilp=ilp, interpret=True)
    want = ref.ref_stress_vpu(x, iters=16, ilp=ilp)
    _allclose(out, want, rtol=1e-5, atol=1e-5)


def test_stress_hbm():
    x = jax.random.normal(K(0), (2048, 128), jnp.bfloat16)
    out = stressors.stress_hbm(x, interpret=True)
    _allclose(out, x, rtol=0, atol=0)


@pytest.mark.parametrize("stride", [1, 8, 32])
def test_stress_vmem(stride):
    x = jax.random.normal(K(0), (512, 128), jnp.float32)
    out = stressors.stress_vmem(x, iters=8, stride=stride, interpret=True)
    want = ref.ref_stress_vmem(x, iters=8, stride=stride)
    _allclose(out, want, rtol=1e-5, atol=1e-5)
