"""HLO analyzer and sharding-rule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import hlo
from repro.parallel import sharding as shd

SAMPLE = """
HloModule jit_f

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum.1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%sum.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%cond.1 (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (x0: f32[8,8]) -> f32[8,8] {
  %x0 = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %x0)
  %w2 = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_hlo_trip_count_multiplies_flops_and_collectives():
    st = hlo.analyze(SAMPLE)
    # dot: 2*8*8*8 = 1024 flops, x7 loop trips
    assert st.mxu_flops == 1024 * 7
    # all-reduce: 2 * 256B result traffic x7
    assert st.coll_bytes_by_kind["all-reduce"] == 2 * 256 * 7
    assert st.coll_count_by_kind["all-reduce"] == 7


def test_hlo_parse_handles_nested_tuple_headers():
    mod = hlo.parse_module(SAMPLE)
    assert set(mod.comps) == {"body.1", "sum.1", "cond.1", "main"}
    assert mod.mult["body.1"] == 7
    assert mod.mult["main"] == 1


# ----------------------------- sharding ------------------------------- #
def _mesh():
    # single-device "mesh" stand-in with fake sizes for rule checks
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    return FakeMesh()


def test_param_rules():
    import jax.tree_util as jtu
    from repro.configs.registry import get_config
    cfg = get_config("qwen3-1.7b")
    mesh = _mesh()
    leaf = jax.ShapeDtypeStruct((28, 2048, 6144), jnp.bfloat16)
    s = shd._param_rule("stack/mlp/w_gate", cfg, "fsdp_tp", mesh, 3)
    assert s == P(None, "data", "model")
    s = shd._param_rule("stack/attn/wk", cfg, "fsdp_tp", mesh, 3)
    assert s[-1] is None      # kv heads (8) don't divide model axis (16)
    s = shd._param_rule("stack/attn/q_norm", cfg, "fsdp_tp", mesh, 2)
    assert all(ax is None for ax in s)   # replicated


def test_sanitize_drops_nondivisible():
    mesh = _mesh()
    s = shd.sanitize(P("model", "data"), (504, 1280), mesh)
    assert s == P(None, "data")          # hubert vocab 504 % 16 != 0
    s = shd.sanitize(P(("data",), None), (1, 128), mesh)
    assert s == P(None, None)            # batch 1 can't shard


def test_recipe_picker():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    big, small = get_config("llama3-405b"), get_config("qwen3-1.7b")
    assert shd.pick_recipe(big, SHAPES["train_4k"]) == "fsdp_tp"
    assert shd.pick_recipe(big, SHAPES["decode_32k"]) == "tp2d_serve"
    assert shd.pick_recipe(small, SHAPES["decode_32k"]) == "tp_serve"
