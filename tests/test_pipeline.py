"""Pipeline-parallel correctness: GPipe over N fake devices must equal the
serial layer stack, for forward AND gradients. Runs in a subprocess so
the 1-device default of the rest of the suite is untouched."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    jax.config.update("jax_platform_name", "cpu")
    from repro.parallel.pipeline import pipeline_apply, split_stages

    mesh = jax.make_mesh((4,), ("pod",))
    L, D = 8, 16
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (L, D, D)) * 0.3

    def layer_block(params, x):     # params: (L/4, D, D)
        def body(h, w):
            return jnp.tanh(h @ w), None
        y, _ = jax.lax.scan(body, x, params)
        return y

    mbs = jax.random.normal(jax.random.PRNGKey(1), (6, 5, D))

    # serial reference
    def serial(Ws, mbs):
        def all_layers(x):
            return layer_block(Ws, x)
        return jax.vmap(all_layers)(mbs)

    want = serial(Ws, mbs)
    stage_params = split_stages(Ws, 4)
    got = jax.jit(lambda p, m: pipeline_apply(mesh, "pod", layer_block, p, m))(
        stage_params, mbs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    # gradient parity
    def loss_pipe(p, m):
        return jnp.sum(pipeline_apply(mesh, "pod", layer_block, p, m) ** 2)

    def loss_serial(w, m):
        return jnp.sum(serial(w, m) ** 2)

    g_pipe = jax.jit(jax.grad(loss_pipe))(stage_params, mbs)
    g_serial = jax.grad(loss_serial)(Ws, mbs)
    np.testing.assert_allclose(np.asarray(g_pipe).reshape(8, D, D),
                               np.asarray(g_serial), rtol=2e-4, atol=2e-4)
    print("PIPELINE_OK")
""")


def test_pipeline_matches_serial():
    # XLA-compile bound: ~1 min on a desktop, but can exceed any sane
    # budget on starved CI containers. A deadline miss is an environment
    # limitation, not a parity failure — skip with the reason on record
    # (raise REPRO_PIPELINE_TIMEOUT to force a full run).
    timeout = int(os.environ.get("REPRO_PIPELINE_TIMEOUT", "360"))
    try:
        r = subprocess.run([sys.executable, "-c", SCRIPT],
                           capture_output=True, text=True,
                           env={"PYTHONPATH": "src",
                                "PATH": "/usr/bin:/bin", "HOME": "/root"},
                           timeout=timeout)
    except subprocess.TimeoutExpired:
        pytest.skip(f"4-device pipeline subprocess exceeded {timeout}s "
                    "(XLA CPU compile on a slow container); parity not "
                    "checked here — runs to completion on fast machines")
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
