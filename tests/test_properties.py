"""Property-based tests (hypothesis) on system invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import TPU_V5E, H100, KernelProfile, estimate
from repro.core.resources import RESOURCE_AXES
from repro.core.scheduler import evaluate_pair
from repro.core.profile import WorkloadProfile
from repro.models.attention import flashref_attention, reference_attention
from repro.models.ssm import mamba1_scan
from repro.kernels.ref import ref_ssm_scan

AX = st.sampled_from(["mxu", "vpu", "issue", "hbm", "smem"])


def _prof(name, util_map, dev=TPU_V5E):
    d = {r: 0.0 for r in RESOURCE_AXES}
    for a, f in util_map.items():
        d[a] = f * dev.capacity(a)
    return KernelProfile(name, demand=d, duration=1.0)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(AX, st.floats(0.05, 0.95)), min_size=1, max_size=4))
def test_estimator_slowdowns_at_least_one(utils):
    """No kernel ever speeds up from contention."""
    ks = [_prof(f"k{i}", {a: f}) for i, (a, f) in enumerate(utils)]
    r = estimate(ks, TPU_V5E)
    assert all(s >= 1.0 - 1e-9 for s in r.slowdowns.values())


@settings(max_examples=40, deadline=None)
@given(AX, st.floats(0.1, 0.9), st.floats(0.05, 0.5))
def test_estimator_monotone_in_background_load(axis, big, small):
    """More background load on the same axis never helps."""
    k = _prof("k", {axis: 0.6})
    lo = estimate([k, _prof("bg", {axis: small})], TPU_V5E).slowdowns["k"]
    hi = estimate([k, _prof("bg", {axis: min(big + small, 0.99)})],
                  TPU_V5E).slowdowns["k"]
    assert hi >= lo - 1e-9


@settings(max_examples=30, deadline=None)
@given(AX, AX, st.floats(0.2, 0.9))
def test_disjoint_axes_do_not_interfere(a1, a2, f):
    if a1 == a2:
        return
    r = estimate([_prof("x", {a1: f}), _prof("y", {a2: f})], TPU_V5E)
    assert max(r.slowdowns.values()) < 1.6   # only mild inflation possible


@settings(max_examples=25, deadline=None)
@given(st.floats(0.1, 0.9), st.floats(0.1, 0.9))
def test_pair_evaluation_symmetry(fa, fb):
    a = WorkloadProfile("a", (_prof("a", {"mxu": fa}),))
    b = WorkloadProfile("b", (_prof("b", {"hbm": fb}),))
    pab = evaluate_pair(a, b, TPU_V5E)
    pba = evaluate_pair(b, a, TPU_V5E)
    assert abs(pab.throughput_gain - pba.throughput_gain) < 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3),
       st.sampled_from([16, 32, 64]), st.sampled_from([1, 2, 4]))
def test_flashref_equals_reference(b, hk, s, g):
    """flash-equivalent chunked attention == naive oracle, any shape."""
    key = jax.random.PRNGKey(b * 100 + hk * 10 + g)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, s, hk * g, 16), jnp.float32)
    kk = jax.random.normal(k2, (b, s, hk, 16), jnp.float32)
    v = jax.random.normal(k3, (b, s, hk, 16), jnp.float32)
    got = flashref_attention(q, kk, v, "causal", chunk=16)
    want = reference_attention(q, kk, v, "causal")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 48), st.sampled_from([8, 16]), st.sampled_from([4, 8]),
       st.sampled_from([4, 8, 16]))
def test_mamba_chunked_scan_equals_sequential(s, di, n, chunk):
    """Chunked associative scan == sequential recurrence, any chunking."""
    key = jax.random.PRNGKey(s)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (1, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, s, di)) - 2)
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    B = jax.random.normal(ks[3], (1, s, n)) * 0.5
    C = jax.random.normal(ks[4], (1, s, n)) * 0.5
    got, _ = mamba1_scan(x, dt, A, B, C, chunk=chunk)
    want = ref_ssm_scan(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 64))
def test_data_pipeline_seek_property(step, batch_pow):
    """batch_at(s) == iterating to s, for any s."""
    from repro.configs.registry import get_config, tiny_config
    from repro.data import DataConfig, SyntheticLM
    cfg = tiny_config(get_config("qwen3-1.7b"))
    d = DataConfig(seq_len=8, global_batch=2, vocab_size=cfg.vocab_size,
                   seed=batch_pow)
    src = SyntheticLM(cfg, d)
    a = src.batch_at(step)
    src.seek(step)
    b = next(src)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
