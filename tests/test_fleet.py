"""FleetScheduler: admission control, priority eviction, device failure
and recovery, retry/backoff into graceful degradation, and the recovery
invariant — the online fleet state after any fault trace equals a cold
FleetScheduler plan over the surviving devices/workloads."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
from bench_fleet import cold_fleet, fleet_plans_equal  # noqa: E402
from bench_planner import decode_heavy_mix  # noqa: E402

from repro.core import (BEST_EFFORT, SLO, TPU_V5E, FleetConfig,  # noqa: E402
                        FleetScheduler)
from repro.ft.inject import (FakeClock, FaultInjector, arrive,  # noqa: E402
                             depart, kill, slow, storm)

TOL = 1e-9


def mix(n_decode=2, n_aux=2):
    works = decode_heavy_mix(TPU_V5E, n_decode=n_decode, n_aux=n_aux)
    return works[:n_decode], works[n_decode:]


def make_fleet(n_devices=2, clock=None, **cfg_kw):
    cfg_kw.setdefault("max_group_size", 3)
    cfg_kw.setdefault("heartbeat_timeout", 3.0)
    cfg = FleetConfig(**cfg_kw)
    return FleetScheduler({f"dev{i}": TPU_V5E for i in range(n_devices)},
                          cfg, clock=clock or FakeClock()), cfg


# ------------------------------------------------------------------ #
#  admission control                                                  #
# ------------------------------------------------------------------ #
def test_submit_places_and_records_decision():
    decodes, _ = mix()
    fleet, _ = make_fleet()
    d = fleet.submit(decodes[0], priority=SLO)
    assert d.action == "placed" and d.workload == decodes[0].name
    assert d.device in fleet.devices
    assert fleet.plan().placed == {decodes[0].name: d.device}


def test_submit_rejects_bad_priority():
    decodes, _ = mix()
    fleet, _ = make_fleet()
    with pytest.raises(ValueError):
        fleet.submit(decodes[0], priority="urgent")
    assert len(fleet) == 0


def test_remove_unknown_raises_before_mutation():
    fleet, _ = make_fleet()
    with pytest.raises(KeyError):
        fleet.remove("ghost")
    assert fleet.stats["departures"] == 0 and len(fleet.decisions) == 0


def test_storm_bounded_queue_rejects_with_records():
    decodes, auxes = mix(n_decode=1, n_aux=8)
    clock = FakeClock()
    fleet, cfg = make_fleet(n_devices=1, clock=clock, max_group_size=2,
                            queue_limit=2)
    fleet.submit(decodes[0], priority=SLO)
    results = [fleet.submit(a, priority=BEST_EFFORT) for a in auxes]
    actions = [r.action for r in results]
    rejected = [r for r in results if r.action == "rejected"]
    assert rejected, "overflow must be rejected, not grown"
    # rejected workloads are NOT tracked: the pool stays bounded
    assert len(fleet) <= 1 + cfg.max_group_size + cfg.queue_limit + 1
    for r in rejected:
        assert r.workload not in fleet
        assert "queue full" in r.reason
    # everything admitted got an explicit decision
    assert all(a in ("placed", "queued", "rejected") for a in actions)


# ------------------------------------------------------------------ #
#  priority eviction                                                  #
# ------------------------------------------------------------------ #
def test_slo_arrival_evicts_best_effort():
    """One device, full of best-effort work: an SLO arrival must take
    the capacity, with an explicit eviction record for the displaced
    best-effort workload."""
    decodes, auxes = mix(n_decode=1, n_aux=2)
    fleet, _ = make_fleet(n_devices=1, max_group_size=2)
    for a in auxes:
        assert fleet.submit(a, priority=BEST_EFFORT).action == "placed"
    d = fleet.submit(decodes[0], priority=SLO)
    assert d.action == "placed"
    plan = fleet.plan()
    assert plan.placed[decodes[0].name] == "dev0"
    evicted = [x for x in fleet.decisions if x.action == "evicted"]
    assert len(evicted) == 1
    assert evicted[0].workload in {a.name for a in auxes}
    assert evicted[0].priority == BEST_EFFORT
    # the evicted workload stays tracked (queued), never silently dropped
    assert evicted[0].workload in fleet
    assert fleet.workload_state(evicted[0].workload)["state"] == "queued"


def test_evicted_work_returns_when_capacity_does():
    decodes, auxes = mix(n_decode=1, n_aux=2)
    fleet, _ = make_fleet(n_devices=1, max_group_size=2)
    for a in auxes:
        fleet.submit(a, priority=BEST_EFFORT)
    fleet.submit(decodes[0], priority=SLO)
    evicted = next(x.workload for x in fleet.decisions
                   if x.action == "evicted")
    fleet.remove(decodes[0].name)            # SLO departs
    assert fleet.plan().placed.get(evicted) == "dev0"


# ------------------------------------------------------------------ #
#  device failure / recovery                                          #
# ------------------------------------------------------------------ #
def _run_kill_trace(n_devices=3, n_decode=3, n_aux=2, until=25.0):
    decodes, auxes = mix(n_decode=n_decode, n_aux=n_aux)
    clock = FakeClock()
    fleet, cfg = make_fleet(n_devices=n_devices, clock=clock)
    trace = ([arrive(float(i), d, priority=SLO)
              for i, d in enumerate(decodes)]
             + storm(3.0, auxes, priority=BEST_EFFORT)
             + [kill(6.0, "dev1")])
    FaultInjector(fleet, clock).run(trace, until=until)
    return fleet, cfg, decodes, auxes


def test_device_kill_replaces_all_slo_work():
    fleet, _, decodes, _ = _run_kill_trace()
    plan = fleet.plan()
    assert plan.device_states["dev1"] == "dead"
    assert plan.placement_rate([d.name for d in decodes]) == 1.0
    assert all(did != "dev1" for did in plan.placed.values())
    assert fleet.stats["errors"] == 0
    assert any(d.action == "device-dead" for d in fleet.decisions)


def test_dead_device_scheduler_is_drained():
    fleet, _, _, _ = _run_kill_trace()
    dev = fleet.devices["dev1"]
    assert len(dev.sched) == 0 and dev.resident_uids == {}
    snap = dev.sched.snapshot()
    assert snap["workloads"] == [] and snap["cached_pairs"] == 0


def test_online_after_kill_equals_cold_over_survivors():
    fleet, cfg, _, _ = _run_kill_trace()
    survivors = {did: d.model for did, d in fleet.devices.items()
                 if did != "dev1"}
    cold = cold_fleet(fleet, survivors, cfg)
    assert fleet_plans_equal(fleet.plan(), cold.plan(), tol=TOL)


def test_heartbeat_revives_dead_device():
    fleet, _, decodes, auxes = _run_kill_trace()
    fleet.heartbeat("dev1")
    plan = fleet.plan()
    assert plan.device_states["dev1"] == "healthy"
    assert any(d.action == "device-recovered" for d in fleet.decisions)
    # with capacity back, everything places again
    assert plan.placement_rate(
        [w.name for w in decodes + auxes if w.name in fleet]) == 1.0


def test_retry_backoff_ends_in_degraded_not_crash():
    """More SLO work than the fleet can hold: retries back off
    exponentially and end in a final degraded state — tracked, recorded,
    no exception out of the event loop."""
    decodes, _ = mix(n_decode=3, n_aux=0)
    clock = FakeClock()
    fleet, cfg = make_fleet(n_devices=1, clock=clock, max_group_size=2,
                            backoff_base=1.0, max_retries=2)
    trace = [arrive(0.0, d, priority=SLO) for d in decodes]
    FaultInjector(fleet, clock).run(trace, until=20.0)
    plan = fleet.plan()
    assert len(plan.degraded) >= 1
    for name in plan.degraded:
        assert fleet.workload_state(name)["retries"] >= cfg.max_retries
    retries = [d for d in fleet.decisions if d.action == "retry-failed"]
    # exponential backoff is visible in the decision reasons
    assert any("backoff 2.0s" in d.reason for d in retries)
    assert fleet.stats["errors"] == 0
    assert fleet.degraded


def test_degraded_workload_recovers_on_capacity_change():
    decodes, _ = mix(n_decode=3, n_aux=0)
    clock = FakeClock()
    fleet, _ = make_fleet(n_devices=1, clock=clock, max_group_size=2,
                          backoff_base=1.0, max_retries=2)
    FaultInjector(fleet, clock).run(
        [arrive(0.0, d, priority=SLO) for d in decodes], until=20.0)
    stuck = fleet.plan().degraded
    assert stuck
    fleet.add_device("dev1", TPU_V5E)
    fleet.tick()
    assert fleet.plan().degraded == []
    assert fleet.plan().placement_rate(stuck) == 1.0


def test_straggling_device_degrades_and_sheds_slo_work():
    decodes, auxes = mix(n_decode=2, n_aux=2)
    clock = FakeClock()
    fleet, _ = make_fleet(n_devices=2, clock=clock)
    trace = ([arrive(float(i), d, priority=SLO)
              for i, d in enumerate(decodes)]
             + [arrive(2.0, a, priority=BEST_EFFORT) for a in auxes]
             + [slow(4.0, "dev1")])
    FaultInjector(fleet, clock).run(trace, until=10.0)
    plan = fleet.plan()
    assert plan.device_states["dev1"] == "degraded"
    placed = plan.placed
    for d in decodes:                        # SLO left the slow device
        assert placed[d.name] == "dev0"
    assert any(d.action == "device-degraded" for d in fleet.decisions)
    # operator override clears it
    fleet.revive_device("dev1")
    assert fleet.plan().device_states["dev1"] == "healthy"


def test_decommission_migrates_like_a_failure():
    decodes, _ = mix(n_decode=2, n_aux=0)
    fleet, cfg = make_fleet(n_devices=2)
    for d in decodes:
        fleet.submit(d, priority=SLO)
    fleet.decommission("dev0")
    plan = fleet.plan()
    assert plan.device_states["dev0"] == "dead"
    assert plan.placement_rate([d.name for d in decodes]) == 1.0
    fleet.decommission("dev0")               # documented no-op
    survivors = {"dev1": TPU_V5E}
    assert fleet_plans_equal(fleet.plan(),
                             cold_fleet(fleet, survivors, cfg).plan())


def test_rescale_plan_attached_on_chip_loss():
    decodes, _ = mix(n_decode=1, n_aux=0)
    clock = FakeClock()
    fleet, _ = make_fleet(n_devices=2, clock=clock)
    meta = {"mesh_shape": {"data": 4, "model": 2}, "global_batch": 256,
            "num_microbatches": 4, "step": 77}
    d = fleet.submit(decodes[0], priority=SLO, train_meta=meta)
    # chips=1 by default: decommission the hosting device
    fleet.decommission(d.device)
    state = fleet.workload_state(decodes[0].name)
    assert state["rescale"] is not None
    assert state["rescale"].restart_step == 77
    assert state["rescale"].new_chip_count < 8
    assert any(x.action == "rescale-planned" for x in fleet.decisions)


# ------------------------------------------------------------------ #
#  determinism + the no-crash contract                                #
# ------------------------------------------------------------------ #
def test_full_trace_online_equals_cold_and_decisions_deterministic():
    """The bench gate's invariant, via the injector: arrivals, a storm,
    a departure, and a kill — then the online plan equals a cold fleet
    over the survivors, and a second identical run produces an identical
    decision log."""
    def run():
        decodes, auxes = mix(n_decode=3, n_aux=3)
        clock = FakeClock()
        fleet, cfg = make_fleet(n_devices=3, clock=clock)
        trace = ([arrive(float(i), d, priority=SLO)
                  for i, d in enumerate(decodes)]
                 + storm(3.0, auxes, priority=BEST_EFFORT)
                 + [depart(5.0, auxes[0].name), kill(7.0, "dev2")])
        FaultInjector(fleet, clock).run(trace, until=30.0)
        return fleet, cfg

    fleet, cfg = run()
    survivors = {did: d.model for did, d in fleet.devices.items()
                 if d.state != "dead"}
    assert fleet_plans_equal(fleet.plan(),
                             cold_fleet(fleet, survivors, cfg).plan(),
                             tol=TOL)
    fleet2, _ = run()
    assert [repr(d) for d in fleet.decisions] \
        == [repr(d) for d in fleet2.decisions]
    assert fleet.stats == fleet2.stats


def test_event_loop_never_raises():
    """tick() seals internal failures into error decisions."""
    decodes, _ = mix(n_decode=1, n_aux=0)
    fleet, _ = make_fleet(n_devices=1)
    fleet.submit(decodes[0], priority=SLO)

    def boom(scope, retry_due=frozenset()):
        raise RuntimeError("injected bug")

    fleet.planner.plan = boom
    fleet.tick(now=1e9)                      # forces a dead-device replan
    errors = [d for d in fleet.decisions if d.action == "error"]
    assert errors and fleet.stats["errors"] >= 1
    assert "injected bug" in errors[-1].reason


def test_snapshot_reports_fleet_telemetry():
    decodes, auxes = mix()
    fleet, _ = make_fleet(n_devices=2)
    fleet.submit(decodes[0], priority=SLO)
    fleet.submit(auxes[0], priority=BEST_EFFORT)
    snap = fleet.snapshot()
    assert set(snap["devices"]) == {"dev0", "dev1"}
    for d in snap["devices"].values():
        assert {"state", "model", "chips", "sched"} <= set(d)
    assert set(snap["workloads"]) == {decodes[0].name, auxes[0].name}
    assert snap["stats"]["arrivals"] == 2


# ------------------------------------------------------------------ #
#  batched storm admission (submit_many)                              #
# ------------------------------------------------------------------ #
def test_submit_many_matches_sequential_with_one_replan():
    decodes, auxes = mix(n_decode=3, n_aux=5)
    works = decodes + auxes
    prios = [SLO] * 3 + [BEST_EFFORT] * 5
    seq, _ = make_fleet(n_devices=3)
    for w, p in zip(works, prios):
        seq.submit(w, priority=p)
    bat, _ = make_fleet(n_devices=3)
    decisions = bat.submit_many(list(zip(works, prios)))
    # same final plan as one-at-a-time admission...
    assert fleet_plans_equal(bat.plan(), seq.plan())
    # ...but one deduplicated replay instead of one per arrival
    assert bat.stats["replans"] == 1
    assert seq.stats["replans"] == len(works)
    assert [d.workload for d in decisions] == [w.name for w in works]
    assert bat.stats["arrivals"] == len(works)


def test_submit_many_bounded_queue_and_dedup():
    decodes, auxes = mix(n_decode=1, n_aux=8)
    fleet, cfg = make_fleet(n_devices=1, max_group_size=2, queue_limit=2)
    fleet.submit(decodes[0], priority=SLO)
    decisions = fleet.submit_many(
        [(a, BEST_EFFORT) for a in auxes]
        + [(auxes[0], BEST_EFFORT)])         # duplicate name in the batch
    # one decision per DISTINCT name, in first-submission order
    assert [d.workload for d in decisions] == [a.name for a in auxes]
    rejected = [d for d in decisions if d.action == "rejected"]
    assert rejected, "overflow must be rejected, not grown"
    for r in rejected:
        assert r.workload not in fleet
        assert "queue full" in r.reason
    assert len(fleet) <= 1 + cfg.max_group_size + cfg.queue_limit + 1


def test_submit_many_empty_and_bad_priority():
    fleet, _ = make_fleet()
    assert fleet.submit_many([]) == []
    decodes, _ = mix()
    with pytest.raises(ValueError):
        fleet.submit_many([(decodes[0], "urgent")])
    assert len(fleet) == 0 and fleet.stats["arrivals"] == 0


def test_injector_batches_same_tick_storm():
    decodes, auxes = mix(n_decode=1, n_aux=4)
    clock = FakeClock()
    fleet, _ = make_fleet(n_devices=2, clock=clock)
    replans_at = {}
    trace = ([arrive(0.0, decodes[0], priority=SLO)]
             + storm(1.0, auxes, priority=BEST_EFFORT))
    FaultInjector(
        fleet, clock,
        on_tick=lambda f, now: replans_at.setdefault(now, f.stats["replans"])
    ).run(trace, until=3.0)
    assert replans_at[1.0] - replans_at[0.0] == 1


# ------------------------------------------------------------------ #
#  price-cache reverse index (departures are O(keys touched))        #
# ------------------------------------------------------------------ #
def test_drop_prices_clears_caches_via_reverse_index():
    """Removing a workload must purge every cached price and
    representative involving its uid — through the uid -> keys reverse
    index, not a full cache scan — and leave group-mates' other entries
    intact."""
    decodes, auxes = mix(n_decode=2, n_aux=2)
    fleet, _ = make_fleet(n_devices=2)
    for d in decodes:
        fleet.submit(d, priority=SLO)
    for a in auxes:
        fleet.submit(a, priority=BEST_EFFORT)
    victim = decodes[0].name
    uid = fleet._tracked[victim].uid
    assert uid in fleet._uid_price_keys
    assert any(uid in key[1] for key in fleet._price_cache)
    fleet.remove(victim)
    # reverse index entries gone...
    assert uid not in fleet._uid_price_keys
    assert uid not in fleet._uid_rep_keys
    # ...and no cache entry references the departed uid any more
    assert not any(uid in key[1] for key in fleet._price_cache)
    assert not any(key[0] == uid for key in fleet._reps)
    # survivors keep their cached prices (the replan after removal
    # reprices from a warm cache, not from scratch)
    live_uids = {t.uid for t in fleet._tracked.values()}
    assert any(set(key[1]) <= live_uids for key in fleet._price_cache)


def test_drop_prices_shared_key_double_drop():
    """Two group-mates share cached group keys; removing both must not
    raise when the second drop hits keys the first already purged."""
    decodes, _ = mix(n_decode=2, n_aux=0)
    fleet, _ = make_fleet(n_devices=1)
    for d in decodes:
        fleet.submit(d, priority=SLO)
    fleet.remove(decodes[0].name)
    fleet.remove(decodes[1].name)       # must not KeyError
    assert len(fleet) == 0
    assert fleet._uid_price_keys == {} and fleet._uid_rep_keys == {}
