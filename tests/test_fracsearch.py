"""k-way slot-fraction search: candidate enumeration, brute-force oracle
equality, f->0 exclusion semantics, fraction-aware slot feasibility, the
feasible-negative-gain-partition bugfix, scheduler integration invariants
(fractions sum to <= 1, cache round-trips, online == cold), and the
SLO-tight decode-heavy gate where partitioned k-way groups strictly beat
the fixed-grid pair baseline."""
import sys
from math import comb
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
from bench_planner import decode_heavy_mix, random_workloads  # noqa: E402

from repro.core import (FRACTION_FLOOR, LEGACY_SEARCH, TPU_V5E,  # noqa: E402
                        ColocationScheduler, FractionSearchConfig,
                        KernelProfile, WorkloadProfile, estimate,
                        evaluate_group, evaluate_group_partitioned,
                        search_group_fractions, simplex_candidates,
                        solve_scenarios)
from repro.core.estimator import solve_batch  # noqa: E402
from repro.core.fracsearch import refinement_candidates  # noqa: E402
from repro.core.profile import ProfileMatrix  # noqa: E402
from repro.core.resources import H100, RESOURCE_AXES  # noqa: E402
from repro.core.scenario import Scenario  # noqa: E402

TOL = 1e-9


def cold(works, dev=TPU_V5E, k=2, search=None):
    s = ColocationScheduler(dev, max_group_size=k, fraction_search=search)
    for w in works:
        s.submit(w)
    return s


# ------------------------------------------------------------------ #
#  Candidate enumeration                                              #
# ------------------------------------------------------------------ #
def test_simplex_candidates_properties():
    for k, steps in ((2, 4), (2, 8), (3, 5), (3, 8), (4, 6)):
        cands = simplex_candidates(k, steps)
        assert len(cands) == comb(steps - 1, k - 1)
        assert len(set(cands)) == len(cands)
        for vec in cands:
            assert len(vec) == k
            assert abs(sum(vec) - 1.0) <= 1e-12
            assert all(f >= 1.0 / steps - 1e-12 for f in vec)
        assert cands == sorted(cands)          # lexicographic order


def test_simplex_k2_matches_legacy_grid():
    """The coarse k=2 grid at 4 steps IS the seed's fixed grid (first
    member ascending) — the compatibility anchor of LEGACY_SEARCH."""
    assert simplex_candidates(2, 4) == [(0.25, 0.75), (0.5, 0.5),
                                        (0.75, 0.25)]


def test_simplex_candidates_validation():
    with pytest.raises(ValueError, match="positive parts"):
        simplex_candidates(3, 2)
    with pytest.raises(ValueError, match="coarse_steps"):
        FractionSearchConfig(coarse_steps=1)
    with pytest.raises(ValueError, match="refine_levels"):
        FractionSearchConfig(refine_levels=-1)


# ------------------------------------------------------------------ #
#  Brute-force oracle equality                                        #
# ------------------------------------------------------------------ #
def _oracle_search(works, dev, cfg):
    """Independent scalar reimplementation of the search: price every
    candidate with evaluate_group, apply the documented selection rule
    (feasible max-gain, earliest on ties; else least-violating), then
    the refinement levels around the running best."""
    names = [w.name for w in works]
    slos = [w.slo_slowdown for w in works]
    times = [w.total_time(dev) for w in works]

    def price(vec):
        pl = evaluate_group(works, dev, dict(zip(names, vec)))
        slows = [pl.predicted_slowdown[n] for n in names]
        viol = max(s / max(o, 1e-12) for s, o in zip(slows, slos))
        return (pl.meets_slo, pl.throughput_gain, viol, vec, slows)

    def better(cand, cur):
        if cur is None:
            return True
        if cand[0] != cur[0]:
            return cand[0]
        return (cand[1] > cur[1]) if cand[0] else (cand[2] < cur[2])

    best = None
    steps = cfg.steps_for(len(works))
    for vec in simplex_candidates(len(works), steps):
        cand = price(vec)
        if better(cand, best):
            best = cand
    for level in range(1, cfg.refine_levels + 1):
        delta = 1.0 / (steps * 2 ** level)
        for vec in refinement_candidates(best[3], times, best[4], slos,
                                         best[0], delta):
            cand = price(vec)
            if better(cand, best):
                best = cand
    return best


@pytest.mark.parametrize("k", [2, 3])
def test_search_matches_bruteforce_oracle(k):
    """The batched, deduplicated search must equal the scalar grid
    oracle at 1e-9 — fractions bit-identical, gains/slowdowns at TOL —
    across random groups (feasible and infeasible outcomes both)."""
    rng = np.random.default_rng(17)
    cfg = FractionSearchConfig()
    pool = random_workloads(rng, 6 * k, TPU_V5E)
    groups = [pool[i * k:(i + 1) * k] for i in range(6)]
    got = search_group_fractions(groups, TPU_V5E, cfg)
    for g, r in zip(groups, got):
        meets, gain, _, vec, slows = _oracle_search(g, TPU_V5E, cfg)
        assert r.meets_slo == meets
        assert r.fractions == tuple(vec)
        assert r.gain == pytest.approx(gain, rel=TOL, abs=TOL)
        for w, s in zip(g, slows):
            assert r.slowdowns[w.name] == pytest.approx(s, rel=TOL, abs=TOL)


def test_search_on_decode_heavy_mix_matches_oracle():
    """Same oracle pin on the engineered SLO-tight mix (feasible
    partitioned triples with extreme refined fractions)."""
    mix = decode_heavy_mix(TPU_V5E)
    cfg = FractionSearchConfig()
    groups = [mix[:2], mix[:3], [mix[0], mix[1], mix[4]]]
    got = search_group_fractions(groups, TPU_V5E, cfg)
    for g, r in zip(groups, got):
        meets, gain, _, vec, _ = _oracle_search(g, TPU_V5E, cfg)
        assert r.meets_slo == meets
        assert r.fractions == tuple(vec)
        assert r.gain == pytest.approx(gain, rel=TOL, abs=TOL)


def test_search_explicit_candidates_matches_legacy_loop():
    """The explicit-candidates path (what evaluate_group_partitioned's
    deprecated `fractions` argument uses) equals a hand-rolled
    first-member sweep over evaluate_group."""
    rng = np.random.default_rng(23)
    works = random_workloads(rng, 3, TPU_V5E)
    names = [w.name for w in works]
    fracs = (0.25, 0.5, 0.75)
    cands = [[(f, (1.0 - f) / 2, (1.0 - f) / 2) for f in fracs]]
    res = search_group_fractions([works], TPU_V5E, candidates=cands)[0]
    best = None
    for vec in cands[0]:
        pl = evaluate_group(works, TPU_V5E, dict(zip(names, vec)))
        if pl.meets_slo and (best is None
                             or pl.throughput_gain > best.throughput_gain):
            best = pl
    if best is None:
        assert not res.meets_slo
    else:
        assert res.meets_slo
        assert dict(zip(names, res.fractions)) == best.slot_fraction
        assert res.gain == pytest.approx(best.throughput_gain, rel=TOL,
                                         abs=TOL)


def test_scheduler_dense_pair_search_matches_generic():
    """The scheduler prices SLO-failing pairs on a dense array fast path
    (`_search_pair_fractions`); it must produce exactly what the generic
    `search_group_fractions` produces for the same pairs — fractions
    bit-identical, slowdowns/gains at 1e-9 — across random pools where
    many pairs violate (the lockstep contract of the two code paths)."""
    rng = np.random.default_rng(31)
    works = random_workloads(rng, 14, TPU_V5E)
    sched = cold(works)
    sched.plan()
    checked = 0
    for (ui, uj), price in sched._pair.items():
        i = next(k for k, w in enumerate(works) if sched._uid[w.name] == ui)
        j = next(k for k, w in enumerate(works) if sched._uid[w.name] == uj)
        full = evaluate_group([works[i], works[j]], TPU_V5E)
        if full.meets_slo:
            continue                      # partition search never ran
        checked += 1
        res = search_group_fractions([[works[i], works[j]]], TPU_V5E,
                                     sched.search)[0]
        slow_i, slow_j, gain, meets, f_i, f_j = price
        assert meets == res.meets_slo
        if not meets:
            continue                      # cached as the full-share price
        assert (f_i, f_j) == res.fractions
        assert gain == pytest.approx(res.gain, rel=TOL, abs=TOL)
        assert slow_i == pytest.approx(res.slowdowns[works[i].name],
                                       rel=TOL, abs=TOL)
        assert slow_j == pytest.approx(res.slowdowns[works[j].name],
                                       rel=TOL, abs=TOL)
    assert checked >= 10, "draw exercised too few failing pairs"


def test_search_rejects_singleton_groups():
    rng = np.random.default_rng(29)
    w = random_workloads(rng, 1, TPU_V5E)
    with pytest.raises(ValueError, match=">= 2"):
        search_group_fractions([w], TPU_V5E)


def test_search_empty_candidates_degrades_gracefully():
    """Zero explicit candidates must yield an infeasible no-fraction
    result (and the partitioned wrapper must fall back to the full-share
    placement), not a crash."""
    rng = np.random.default_rng(37)
    works = random_workloads(rng, 2, TPU_V5E)
    res = search_group_fractions([works], TPU_V5E, candidates=[[]])[0]
    assert not res.meets_slo and res.fractions == ()
    full = evaluate_group(works, TPU_V5E)
    got = evaluate_group_partitioned(works, TPU_V5E, fractions=())
    assert got.slot_fraction == {}
    assert got.meets_slo == full.meets_slo
    assert got.throughput_gain == pytest.approx(full.throughput_gain,
                                                rel=TOL, abs=TOL)


def test_partition_curve_validates_member_index():
    from repro.core import partition_curve
    rng = np.random.default_rng(43)
    works = random_workloads(rng, 2, TPU_V5E)
    with pytest.raises(ValueError, match="out of range"):
        partition_curve(works, TPU_V5E, member=5, fractions=(0.25,))


# ------------------------------------------------------------------ #
#  f -> 0 exclusion semantics (the floor the search relies on)        #
# ------------------------------------------------------------------ #
def _mk_kernel(name, util, dev=TPU_V5E):
    d = {r: util * dev.capacity(r) for r in RESOURCE_AXES}
    return KernelProfile(name, demand=d, duration=1.0)


def test_zero_fraction_excludes_member():
    """A member at fraction 0 is ABSENT: the others solve exactly as if
    it were not in the scenario, and its own slowdown is +inf."""
    a, b, c = (_mk_kernel(n, u) for n, u in
               (("a", 0.6), ("b", 0.5), ("c", 0.4)))
    pm = ProfileMatrix.from_profiles([a, b, c])
    with_c = solve_batch(pm, np.array([[0, 1, 2]]), TPU_V5E,
                         np.array([[1.0, 1.0, 0.0]]))
    without_c = solve_batch(pm, np.array([[0, 1]]), TPU_V5E)
    for j in range(2):
        assert with_c.slowdowns[0, j] == pytest.approx(
            without_c.slowdowns[0, j], rel=TOL, abs=TOL)
        assert with_c.speeds[0, j] == pytest.approx(
            without_c.speeds[0, j], rel=TOL, abs=TOL)
    assert np.isinf(with_c.slowdowns[0, 2])
    assert with_c.speeds[0, 2] == 0.0


def test_fraction_floor_boundary():
    """At the floor the member is excluded; just above it, it is live
    (with the documented ~1/f demand scaling) — no 1e6x-inflated ghost
    in between."""
    a, b = _mk_kernel("a", 0.3), _mk_kernel("b", 0.3)
    pm = ProfileMatrix.from_profiles([a, b])
    at_floor = solve_batch(pm, np.array([[0, 1]]), TPU_V5E,
                           np.array([[1.0, FRACTION_FLOOR]]))
    assert np.isinf(at_floor.slowdowns[0, 1])
    assert at_floor.slowdowns[0, 0] == pytest.approx(1.0, rel=1e-6)
    above = solve_batch(pm, np.array([[0, 1]]), TPU_V5E,
                        np.array([[1.0, 64 * FRACTION_FLOOR]]))
    assert np.isfinite(above.slowdowns[0, 1])
    # the live co-runner's huge scaled demand must not starve member a
    # beyond the axis capacity it actually consumes
    assert np.isfinite(above.slowdowns[0, 0])


def test_exclusion_matches_estimate_wrapper():
    """The exclusion semantics flow through the name-keyed wrapper."""
    a, b = _mk_kernel("a", 0.7), _mk_kernel("b", 0.9)
    r = estimate([a, b], TPU_V5E, {"b": 0.0})
    solo = estimate([a], TPU_V5E)
    assert r.slowdowns["a"] == pytest.approx(solo.slowdowns["a"], rel=TOL)
    assert np.isinf(r.slowdowns["b"])


# ------------------------------------------------------------------ #
#  Fraction-aware slot feasibility                                    #
# ------------------------------------------------------------------ #
def test_slot_feasibility_scales_with_fractions():
    """Two members each needing 80% of the SMs over-commit at full
    share; partitioned to half the device each, their occupancy is
    scaled by the fractions and fits."""
    d = {r: 0.1 * H100.capacity(r) for r in RESOURCE_AXES}
    big = int(0.8 * H100.n_slots)
    a = KernelProfile("a", demand=dict(d), duration=1.0, slots_needed=big)
    b = KernelProfile("b", demand=dict(d), duration=1.0, slots_needed=big)
    pm = ProfileMatrix.from_profiles([a, b])
    full = solve_batch(pm, np.array([[0, 1]]), H100)
    assert not full.feasible_slots[0]
    halved = solve_batch(pm, np.array([[0, 1]]), H100,
                         np.array([[0.5, 0.5]]))
    assert halved.feasible_slots[0]
    # an excluded member's slots do not count at all
    solo = solve_batch(pm, np.array([[0, 1]]), H100,
                       np.array([[1.0, 0.0]]))
    assert solo.feasible_slots[0]


# ------------------------------------------------------------------ #
#  Bugfix: feasible partitions with gain <= 0 must win over an        #
#  infeasible full-share placement                                    #
# ------------------------------------------------------------------ #
def _negative_gain_pair(dev=TPU_V5E):
    """A pair whose only feasible placement is a partition with NEGATIVE
    packed gain: the victim carries a ghost phase with negative duration
    weight (a synthetic accounting device), making the group's serial
    time negative while the partition decision is exactly the real
    SLO-rescue from the decode-heavy regime."""
    d = {r: 0.0 for r in RESOURCE_AXES}
    d.update({"mxu": 0.4 * dev.capacity("mxu"),
              "hbm": 0.7 * dev.capacity("hbm"),
              "l2": 0.7 * dev.capacity("l2")})
    victim_kernel = KernelProfile("victim#step", demand=d, duration=1.0)
    # the victim slows to ~1.167x at full share and 1.0x partitioned; a
    # ghost at 1.1 sits between, so the WORKLOAD slowdown is hugely
    # positive (SLO-violating) at full share and hugely negative
    # (SLO-meeting) partitioned, while the group's serial time is < 0
    ghost = KernelProfile("victim#ghost", demand={r: 0.0 for r in
                                                  RESOURCE_AXES},
                          duration=1.1, duration_weight=-1.0)
    victim = WorkloadProfile("victim", (victim_kernel, ghost),
                             slo_slowdown=1.2)
    da = {r: 0.0 for r in RESOURCE_AXES}
    da.update({"mxu": 0.9 * dev.capacity("mxu"),
               "vpu": 0.2 * dev.capacity("vpu"),
               "hbm": 0.6 * dev.capacity("hbm"),
               "l2": 0.6 * dev.capacity("l2")})
    aggressor = WorkloadProfile(
        "aggressor", (KernelProfile("aggressor#step", demand=da,
                                    duration=0.4, duration_weight=0.05),),
        slo_slowdown=50.0)
    return victim, aggressor


def test_negative_gain_partition_is_kept():
    victim, aggressor = _negative_gain_pair()
    full = evaluate_group([victim, aggressor], TPU_V5E)
    assert not full.meets_slo            # the placement partition rescues
    part = evaluate_group_partitioned([victim, aggressor], TPU_V5E)
    assert part.meets_slo, "feasible partition was discarded"
    assert part.slot_fraction            # a real partition, not full share
    assert part.throughput_gain <= 0.0   # the regression trigger


def test_negative_gain_partition_scheduler_pair_cache_bit_identical():
    """The batched pair pricing must cache the same feasible partition
    the scalar evaluate_group_partitioned finds — bit-identical
    fractions, same gain/slowdowns at 1e-9 (the `best_gain = 0` twin of
    the `> 0` comparison discarded it before)."""
    victim, aggressor = _negative_gain_pair()
    part = evaluate_group_partitioned([victim, aggressor], TPU_V5E)
    sched = cold([victim, aggressor])
    sched.plan()
    (price,) = sched._pair.values()
    slow_v, slow_a, gain, meets, f_v, f_a = price
    assert meets, "pair cached as infeasible despite feasible partition"
    assert f_v == part.slot_fraction["victim"]
    assert f_a == part.slot_fraction["aggressor"]
    assert gain == pytest.approx(part.throughput_gain, rel=TOL, abs=TOL)
    assert slow_v == pytest.approx(part.predicted_slowdown["victim"],
                                   rel=TOL, abs=TOL)
    assert slow_a == pytest.approx(part.predicted_slowdown["aggressor"],
                                   rel=TOL, abs=TOL)


# ------------------------------------------------------------------ #
#  Scheduler integration invariants                                   #
# ------------------------------------------------------------------ #
def _assert_plans_match(got, want):
    assert [p.workloads for p in got.placements] == \
        [p.workloads for p in want.placements]
    assert got.solo == want.solo
    for g, w in zip(got.placements, want.placements):
        assert g.slot_fraction == w.slot_fraction
        assert abs(g.throughput_gain - w.throughput_gain) <= TOL


def test_partitioned_group_fractions_sum_to_at_most_one():
    """Every placement's fractions are a valid partition: each member's
    share above the exclusion floor and the group total <= 1."""
    mix = decode_heavy_mix(TPU_V5E)
    for k in (2, 3, 4):
        plan = cold(mix, k=k).plan()
        for p in plan.placements:
            if not p.slot_fraction:
                continue
            total = sum(p.slot_fraction.values())
            assert total <= 1.0 + 1e-12, (k, p)
            assert all(f > FRACTION_FLOOR for f in p.slot_fraction.values())
            assert set(p.slot_fraction) == set(p.workloads)


def test_kway_partitioned_groups_beat_fixed_grid_pairs():
    """THE acceptance gate: on the SLO-tight decode-heavy mix, the k-way
    scheduler with the default fraction search strictly beats the
    legacy fixed-grid pair baseline in total gain, and does it with
    partitioned groups of size > 2."""
    mix = decode_heavy_mix(TPU_V5E)
    baseline = cold(mix, k=2, search=LEGACY_SEARCH).plan()
    kway = cold(mix, k=3).plan()
    assert kway.total_gain > baseline.total_gain + 1e-6
    grown = [p for p in kway.placements
             if len(p.workloads) > 2 and p.slot_fraction]
    assert grown, "no partitioned k-way group was placed"
    for p in kway.placements:
        assert p.meets_slo


def test_partition_cache_roundtrips_through_remove_submit():
    """Removing and re-submitting a member of a partitioned group must
    re-price it to the identical partition (cache drop + lazy re-price,
    not a stale or corrupted entry)."""
    mix = decode_heavy_mix(TPU_V5E)
    sched = cold(mix, k=3)
    before = sched.plan()
    target = next(p for p in before.placements
                  if len(p.workloads) > 2 and p.slot_fraction)
    member = target.workloads[0]
    profile = next(w for w in mix if w.name == member)
    sched.remove(member)
    mid = sched.plan()
    assert member not in {n for p in mid.placements for n in p.workloads}
    sched.submit(profile)          # re-arrives at the END of the order
    after = sched.plan()
    reordered = [w for w in mix if w.name != member] + [profile]
    _assert_plans_match(after, cold(reordered, k=3).plan())
    # the member lands in a partitioned k-way group again, with the
    # exact fractions/gain its group had before (the mix is symmetric)
    regrown = next(p for p in after.placements if member in p.workloads)
    assert len(regrown.workloads) > 2 and regrown.slot_fraction
    assert regrown.throughput_gain == pytest.approx(
        target.throughput_gain, rel=TOL, abs=TOL)
    assert sorted(regrown.slot_fraction.values()) == pytest.approx(
        sorted(target.slot_fraction.values()), rel=TOL, abs=TOL)


def test_online_plan_with_partitioned_groups_matches_cold():
    """Arrivals/departures over the SLO-tight mix: every online plan()
    must equal a cold plan on the surviving set, including partitioned
    k-way groups and their fractions."""
    rng = np.random.default_rng(41)
    pool = decode_heavy_mix(TPU_V5E) + random_workloads(rng, 6, TPU_V5E)
    rng.shuffle(pool)
    sched = ColocationScheduler(TPU_V5E, max_group_size=3)
    resident = []
    fresh = list(pool)
    for _ in range(14):
        if resident and rng.random() < 0.4:
            victim = resident.pop(int(rng.integers(len(resident))))
            sched.remove(victim.name)
        else:
            if not fresh:
                break
            w = fresh.pop()
            resident.append(w)
            sched.submit(w)
        _assert_plans_match(sched.plan(), cold(resident, k=3).plan())
