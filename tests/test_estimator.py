"""Interference-estimator validation against the paper's MEASURED numbers.

Each test encodes one of the paper's experiments as KernelProfiles built
from the NCU metrics the paper reports (utilization fractions over the
kernel's isolated runtime), runs the estimator with the matching GPU
resource model, and checks predicted slowdown/speedup against the paper's
measurement within a tolerance band. This is the faithful-reproduction
axis: same methodology, the paper's hardware numbers as ground truth.
"""
import numpy as np
import pytest

from repro.core import (H100, RTX3090, TPU_V5E, KernelProfile,
                        WorkloadProfile, colocation_speedup, estimate,
                        pairwise_slowdown, sensitivity)
from repro.core.resources import RESOURCE_AXES


def profile_on(dev, name, duration=1.0, ws=0.0, hit=0.0, **axes) -> KernelProfile:
    """Utilization-style builder: axes are FRACTIONS of capacity consumed
    over `duration` seconds of isolated runtime (the NCU-metric view)."""
    d = {r: 0.0 for r in RESOURCE_AXES}
    for ax, frac in axes.items():
        d[ax] = frac * dev.capacity(ax) * duration
    return KernelProfile(name, demand=d, duration=duration,
                         cache_working_set=ws, cache_hit_fraction=hit)


# ----------------------------------------------------------------- #
#  §3 pitfall 1: two issue-saturating compute kernels (IPC 3.99/4)    #
#  measured: 1.73x each when colocated on all SMs                     #
# ----------------------------------------------------------------- #
def test_pitfall1_issue_saturation():
    k1 = profile_on(H100, "compute1", issue=0.99, vpu=0.5)
    k2 = profile_on(H100, "compute2", issue=0.99, vpu=0.5)
    r = estimate([k1, k2], H100)
    # both saturate issue -> ~2x predicted; paper measured 1.73x
    assert 1.6 <= r.slowdowns["compute1"] <= 2.1
    assert r.bottleneck["compute1"] == "issue"


def test_pitfall1_sm_restriction():
    """Usher-style restriction of an issue-bound kernel to 6.25% of SMs
    (its 'achieved occupancy') slows it ~8.6x (paper: 8.57x).
    Occupancy is the WRONG metric: the kernel needs issue slots, not
    resident warps."""
    k = profile_on(H100, "compute", issue=0.99, vpu=0.5)
    r = estimate([k], H100, slot_fraction={"compute": 0.0625})
    assert 7.0 <= r.slowdowns["compute"] <= 17.0


# ----------------------------------------------------------------- #
#  §3 pitfall 2: compute (IPC 3.99) x copy (IPC 0.57, memory-bound)   #
#  measured: copy's execution time doubles under colocation           #
# ----------------------------------------------------------------- #
def test_pitfall2_copy_vs_issue_hog():
    comp = profile_on(H100, "compute", issue=0.99, vpu=0.5)
    copy = profile_on(H100, "copy", issue=0.57 / 4, hbm=0.75, l2=0.4)
    r = estimate([comp, copy], H100)
    s_copy = r.slowdowns["copy"]
    assert 1.5 <= s_copy <= 2.6, s_copy   # paper: ~2x
    # compute itself is barely affected (its own axis saturation persists)
    assert r.slowdowns["compute"] <= 1.3


# ----------------------------------------------------------------- #
#  §4.3 Table 1: LLM decode vs copy-kernel bandwidth sweep            #
#  measured P90 TBT: 16.9 -> 17.6 / 18.38 / 19.92 / 22 ms             #
# ----------------------------------------------------------------- #
def test_table1_membw_contention():
    decode = profile_on(H100, "decode", hbm=0.55, issue=0.10)
    measured = {0.27: 17.6 / 16.9, 0.51: 18.38 / 16.9,
                0.69: 19.92 / 16.9, 0.81: 22.0 / 16.9}
    for bw_util, want in measured.items():
        copy = profile_on(H100, f"copy{bw_util}", hbm=bw_util,
                          issue=0.05)
        r = estimate([decode, copy], H100)
        got = r.slowdowns["decode"]
        assert abs(got - want) / want < 0.25, (bw_util, got, want)


# ----------------------------------------------------------------- #
#  §4.4.3 Table 3: two FP64 kernels, speedup of colocation vs serial  #
#  measured: S1 1.93x, S2 1.87x, S3 1.33x, S4 1.03x                   #
# ----------------------------------------------------------------- #
@pytest.mark.parametrize("util,want,tol", [
    (0.2422, 1.93, 0.10), (0.4771, 1.87, 0.12),
    (0.6942, 1.33, 0.15), (0.9068, 1.03, 0.12)])
def test_table3_fp64_pipeline(util, want, tol):
    # FP64 pipe maps to the vpu axis; IPC stays below the limit (paper)
    a = profile_on(H100, "a", vpu=util, issue=0.49)
    b = profile_on(H100, "b", vpu=util, issue=0.49)
    got = colocation_speedup(a, b, H100)
    assert abs(got - want) / want < tol, (got, want)


# ----------------------------------------------------------------- #
#  §4.4.2 Table 2: Gemma3-1B decode TBT vs ILP-sweep stressor S1..S4  #
#  RTX3090 measured (bs8): 6.08 -> 6.23 / 6.56 / 12.52 ms             #
# ----------------------------------------------------------------- #
def test_table2_ipc_sweep():
    decode = profile_on(RTX3090, "decode", hbm=0.5, issue=0.55 / 4)
    preds = {}
    for ipc, want in [(1.18, 6.23 / 6.08), (2.06, 6.56 / 6.08),
                      (3.45, 12.52 / 6.08)]:
        st = profile_on(RTX3090, f"S{ipc}", issue=ipc / 4, vpu=ipc / 8)
        r = estimate([decode, st], RTX3090)
        preds[ipc] = r.slowdowns["decode"]
        assert abs(preds[ipc] - want) / want < 0.35, (ipc, preds[ipc], want)
    # monotone in stressor IPC, sharp knee near the issue limit
    assert preds[1.18] < preds[2.06] < preds[3.45]
    assert preds[3.45] > 1.6


# ----------------------------------------------------------------- #
#  §4.3 Fig. 3: L2 pollution curve shape                              #
# ----------------------------------------------------------------- #
def test_fig3_l2_pollution_shape():
    """No slowdown while both instances fit in L2; slowdown appears once
    the combined working set spills (paper peak 2.15x at 16MB; we model
    the bandwidth effect, not the thrash-cliff latency spike — deviation
    documented in EXPERIMENTS.md)."""
    slows = []
    for mb in [4, 8, 16, 26, 48]:
        ws = 2 * mb * 1e6   # in+out arrays per instance
        mk = lambda n: profile_on(
            H100, n, hbm=0.94, l2=0.45, issue=0.2, ws=ws, hit=0.95)
        r = estimate([mk("a"), mk("b")], H100)
        slows.append(r.slowdowns["a"])
    assert slows[0] < 1.15 and slows[1] < 1.15        # fits: 16/32MB < 50MB
    assert max(slows[2:]) > 1.5                       # spill: big slowdown
    assert slows[2] >= slows[0]


# ----------------------------------------------------------------- #
#  §4.4.1 Fig. 4: shared-memory (smem/VMEM) bandwidth interference    #
# ----------------------------------------------------------------- #
def test_fig4_smem_interference():
    """GEMM (smem-hungry) vs strided-copy stressor: slowdown grows with
    the stressor's smem pressure (bank conflicts serialize wavefronts).
    Paper: 3.75x for dim-1024 GEMM (high smem-pipe util) at 32-way
    conflicts; 1.79x for dim-2048 (lower smem-pipe util)."""
    gemm_hi = profile_on(H100, "gemm1024", mxu=0.35, smem=0.75, issue=0.4)
    gemm_lo = profile_on(H100, "gemm2048", mxu=0.55, smem=0.40, issue=0.3)
    slows_hi, slows_lo = [], []
    for conflict_util in (0.1, 0.5, 0.95):
        st = profile_on(H100, "strided", smem=conflict_util, issue=0.3)
        slows_hi.append(estimate([gemm_hi, st], H100).slowdowns["gemm1024"])
        slows_lo.append(estimate([gemm_lo, st], H100).slowdowns["gemm2048"])
    # monotone in conflicts; the high-smem-util GEMM is MORE sensitive
    assert slows_hi[0] < slows_hi[1] <= slows_hi[2]
    assert slows_hi[2] > slows_lo[2]
    assert slows_hi[2] > 1.4
    assert slows_lo[2] > 1.2     # paper: even the low-util GEMM slows 1.79x


# ----------------------------------------------------------------- #
#  Sensitivity fingerprints distinguish phases (TPU target)           #
# ----------------------------------------------------------------- #
def test_sensitivity_fingerprint_tpu():
    prefill = profile_on(TPU_V5E, "prefill", mxu=0.7, hbm=0.2)
    decode = profile_on(TPU_V5E, "decode", mxu=0.05, hbm=0.85)
    sp = sensitivity(prefill, TPU_V5E)
    sd = sensitivity(decode, TPU_V5E)
    assert sp.dominant() == "mxu"
    assert sd.dominant() in ("hbm", "l2")
    # complementary profiles colocate well (the scheduler's pairing basis)
    r = estimate([prefill, decode], TPU_V5E)
    assert max(r.slowdowns.values()) < 1.45


def test_estimator_is_symmetric_and_scale_free():
    a = profile_on(TPU_V5E, "a", mxu=0.6, hbm=0.3)
    b = profile_on(TPU_V5E, "b", mxu=0.6, hbm=0.3)
    r = estimate([a, b], TPU_V5E)
    assert abs(r.slowdowns["a"] - r.slowdowns["b"]) < 1e-9
