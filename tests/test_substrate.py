"""Substrate tests: data pipeline, checkpointing (async/atomic/resume/
reshard), fault-tolerance logic, optimizers, trainer loop."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.configs.registry import get_config, tiny_config
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.ft import HeartbeatTracker, StragglerMonitor, plan_rescale
from repro.models import build_model
from repro.train.optimizer import adafactor, adamw, global_norm, warmup_cosine
from repro.train.trainer import Trainer, TrainerConfig


CFG = tiny_config(get_config("qwen3-1.7b"))


# ----------------------------- data ---------------------------------- #
def test_data_deterministic_and_seekable():
    d = DataConfig(seq_len=32, global_batch=4, vocab_size=CFG.vocab_size)
    a = SyntheticLM(CFG, d)
    b = SyntheticLM(CFG, d)
    b.seek(5)
    batches_a = [next(a) for _ in range(8)]
    np.testing.assert_array_equal(batches_a[5]["tokens"], next(b)["tokens"])
    assert batches_a[0]["tokens"].max() < CFG.vocab_size
    assert batches_a[0]["loss_mask"].shape == (4, 32)


def test_data_host_sharding_partitions_batch():
    d0 = DataConfig(seq_len=16, global_batch=8, n_hosts=2, host_id=0)
    d1 = DataConfig(seq_len=16, global_batch=8, n_hosts=2, host_id=1)
    b0 = SyntheticLM(CFG, d0).batch_at(3)
    b1 = SyntheticLM(CFG, d1).batch_at(3)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher_matches_source():
    d = DataConfig(seq_len=16, global_batch=2)
    pf = Prefetcher(SyntheticLM(CFG, d))
    ref = SyntheticLM(CFG, d)
    for _ in range(4):
        np.testing.assert_array_equal(next(pf)["tokens"], next(ref)["tokens"])
    pf.close()


# --------------------------- checkpoint ------------------------------ #
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree), block=True)
    assert mgr.all_steps() == [2, 3]          # keep-2 retention
    step, restored = mgr.restore_latest(like=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(6).reshape(2, 3) * 3)


def test_checkpoint_atomic_crash_safety(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(7, {"x": jnp.ones(3)}, block=True)
    # simulate a crash mid-write: stray .tmp dir must be ignored
    bad = tmp_path / "step_00000009.tmp"
    bad.mkdir()
    (bad / "garbage").write_text("x")
    assert mgr.all_steps() == [7]
    step, _ = mgr.restore_latest(like={"x": jnp.ones(3)})
    assert step == 7


def test_trainer_resume_after_restart(tmp_path):
    model = build_model(CFG)
    d = DataConfig(seq_len=16, global_batch=2, vocab_size=CFG.vocab_size)
    tcfg = TrainerConfig(total_steps=6, checkpoint_dir=str(tmp_path),
                         checkpoint_every=2, log_every=100)
    t1 = Trainer(model, RunConfig(), tcfg)
    t1.fit(SyntheticLM(CFG, d), jax.random.PRNGKey(0))
    assert t1.ckpt_mgr.all_steps()
    # "crash" and restart: resume step must follow the last checkpoint
    t2 = Trainer(model, RunConfig(), tcfg)
    step, params, opt_state = t2.restore_or_init(jax.random.PRNGKey(0))
    assert step == 6   # final checkpoint at step 5 -> resume at 6
    assert opt_state["count"] > 0


# ------------------------------- ft ----------------------------------- #
def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(factor=3.0, warmup=2)
    flagged = [mon.observe(i, 0.1) for i in range(6)]
    assert not any(flagged)
    assert mon.observe(6, 1.0)        # 10x the EWMA
    assert mon.events and mon.events[0]["step"] == 6
    # healthy step after straggle does not poison the baseline
    assert not mon.observe(7, 0.1)


def test_heartbeat_tracker():
    hb = HeartbeatTracker(timeout_s=10)
    hb.beat("host0", now=100.0)
    hb.beat("host1", now=104.0)
    assert hb.dead_workers(now=112.0) == ["host0"]


def test_rescale_plan_preserves_model_axis():
    plan = plan_rescale({"pod": 2, "data": 16, "model": 16}, lost_chips=256,
                        global_batch=256, num_microbatches=4, current_step=77)
    assert plan.new_shape["model"] == 16          # TP must stay intact
    assert plan.new_chip_count <= 2 * 16 * 16 - 256
    assert plan.new_microbatches >= 4             # keep global batch
    assert plan.restart_step == 77


# ---------------------------- optimizer ------------------------------- #
@pytest.mark.parametrize("make", [lambda: adamw(1e-2), lambda: adafactor(1e-2)])
def test_optimizers_reduce_quadratic_loss(make):
    opt = make()
    params = {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.full((256, 256), 2.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2) / p["b"].size

    l0 = loss(params)
    step = jax.jit(lambda p, s: opt.update(jax.grad(loss)(p), s, p))
    for _ in range(300):
        params, state = step(params, state)
    assert loss(params) < 0.1 * l0


def test_grad_accumulation_matches_full_batch():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    d = DataConfig(seq_len=16, global_batch=4, vocab_size=CFG.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in SyntheticLM(CFG, d).batch_at(0).items()}
    from repro.train.trainer import make_train_step
    from repro.train.optimizer import get_optimizer
    opt = get_optimizer("adamw")

    outs = {}
    for k in (1, 2, 4):
        step = make_train_step(model, opt, RunConfig(num_microbatches=k))
        p2, _, m = jax.jit(step)(params, opt.init(params), batch)
        outs[k] = (float(m["loss"]), p2)
    assert abs(outs[1][0] - outs[4][0]) < 2e-2
    diff = global_norm(jax.tree.map(lambda a, b: a - b, outs[1][1], outs[4][1]))
    scale = global_norm(outs[1][1])
    assert float(diff) / float(scale) < 2e-2


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 0.11
    assert float(lr(100)) < float(lr(50)) < float(lr(11))
