"""Scoped repair: the bounded-divergence contract, property-tested.

The ``RepairPlanner`` replaces the fleet's replay-everything loop with
scope-local repair.  Its contract (src/repro/core/repair.py):

  * after ANY mutation sequence, the online fleet's total packed gain is
    >= (1 - divergence_epsilon) x a cold full replay over the same pool
    and surviving devices;
  * the SET of placed SLO workloads matches that cold replay exactly
    (the SLO-fallback rule: scoped repair refuses to be the one that
    queues an SLO tenant);
  * fleets too small for any scope to be local (the default thresholds)
    take the full-replay path every time — the legacy online == cold at
    1e-9 behavior is bit-preserved there.

These are *property* tests: random mutation sequences (arrivals,
departures, decommissions, revives) over several seeds on a 24-device
heterogeneous (v5e/v5p) fleet, with ``full_replay_fraction=1.0`` so the
scoped path is always taken — the adversarial regime for divergence.
"""
import sys
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
from bench_fleet import cold_fleet, fleet_plans_equal  # noqa: E402

from repro.core import (BEST_EFFORT, SLO, TPU_V5E, TPU_V5P,  # noqa: E402
                        FleetConfig, FleetScheduler, KernelProfile,
                        RepairScope, WorkloadProfile)
from repro.core.resources import RESOURCE_AXES  # noqa: E402
from repro.ft.inject import FakeClock  # noqa: E402

N_DEV = 24
SCOPED_CFG = FleetConfig(max_group_size=3, queue_limit=64,
                         heartbeat_timeout=1e9,
                         full_replay_fraction=1.0, repair_probe=4)


def hetero_models(n=N_DEV):
    return {f"dev{i:02d}": (TPU_V5E if i % 2 == 0 else TPU_V5P)
            for i in range(n)}


def rand_workload(rng, name, slo=1.5):
    """Moderate-demand workload: heavier on one randomly chosen axis so
    groups contend mildly, loose 1.5x SLO so full-share triples pass."""
    lean = ("mxu", "hbm")[int(rng.integers(2))]
    u = {"mxu": 0.10, "vpu": 0.04, "issue": 0.05, "hbm": 0.10, "l2": 0.10}
    u[lean] = float(rng.uniform(0.25, 0.45))
    if lean == "hbm":
        u["l2"] = u["hbm"]
    d = {r: u.get(r, 0.0) * TPU_V5E.capacity(r) for r in RESOURCE_AXES}
    return WorkloadProfile(
        name, (KernelProfile(f"{name}#step", demand=d, duration=1.0),),
        slo_slowdown=slo)


def cold_of(fleet, cfg):
    """Cold FULL replay over the online fleet's pool and surviving
    devices: one batched storm through a repair_mode="full" twin is
    exactly one deterministic cold replay."""
    survivors = {did: d.model for did, d in fleet.devices.items()
                 if d.state != "dead"}
    cold = FleetScheduler(survivors, replace(cfg, repair_mode="full"))
    cold.submit_many([(p, prio) for p, prio in fleet.workloads])
    return cold


def run_mutations(seed, steps=40):
    """One random mutation sequence; returns the online fleet."""
    rng = np.random.default_rng(seed)
    clock = FakeClock()
    fleet = FleetScheduler(hetero_models(), SCOPED_CFG, clock=clock)
    pool = []
    next_id = 0
    for _ in range(steps):
        op = float(rng.random())
        if op < 0.55 or not pool:
            w = rand_workload(rng, f"w{next_id}")
            next_id += 1
            fleet.submit(w, priority=SLO if rng.random() < 0.5
                         else BEST_EFFORT)
            pool.append(w.name)
        elif op < 0.82:
            name = pool.pop(int(rng.integers(len(pool))))
            if name in fleet:
                fleet.remove(name)
        elif op < 0.92:
            live = [did for did, d in fleet.devices.items()
                    if d.state == "healthy"]
            if len(live) > N_DEV // 2:
                fleet.decommission(live[int(rng.integers(len(live)))])
        else:
            dead = [did for did, d in fleet.devices.items()
                    if d.state == "dead"]
            if dead:
                fleet.heartbeat(dead[int(rng.integers(len(dead)))])
        clock.advance(1.0)
    return fleet


@pytest.mark.parametrize("seed", range(6))
def test_random_mutations_bounded_divergence(seed):
    """After a random mutation sequence under always-scoped repair, the
    online gain is within epsilon of cold and the SLO sets match."""
    fleet = run_mutations(seed)
    assert fleet.stats["errors"] == 0
    assert fleet.stats["scoped_repairs"] > 0   # the scoped path actually ran
    plan = fleet.plan()
    cplan = cold_of(fleet, SCOPED_CFG).plan()
    eps = SCOPED_CFG.divergence_epsilon
    assert plan.total_gain >= (1.0 - eps) * cplan.total_gain - 1e-9, (
        f"divergence contract broken: online {plan.total_gain:.6f} < "
        f"(1-{eps}) x cold {cplan.total_gain:.6f}")
    slo_names = {p.name for p, prio in fleet.workloads if prio == SLO}
    online_slo = {n for n in slo_names if n in plan.placed}
    cold_slo = {n for n in slo_names if n in cplan.placed}
    assert online_slo == cold_slo


@pytest.mark.parametrize("seed", range(3))
def test_scoped_repairs_touch_few_devices(seed):
    """Scoped repairs stay local: every non-full repair touches at most
    scope devices + probe + displaced groups, far below the fleet."""
    fleet = run_mutations(seed)
    scoped = [r for r in fleet.repairs if not r.full]
    assert scoped
    assert max(r.devices_touched for r in scoped) < N_DEV


def test_small_fleet_defaults_bit_preserve_full_replay():
    """With the default thresholds a 4-device fleet can never pass the
    locality test, so EVERY replan is a full replay and the historical
    online == cold at 1e-9 contract holds bit-for-bit."""
    cfg = FleetConfig(max_group_size=3, heartbeat_timeout=1e9)
    models = {f"dev{i}": TPU_V5E for i in range(4)}
    fleet = FleetScheduler(models, cfg, clock=FakeClock())
    rng = np.random.default_rng(7)
    for i in range(8):
        fleet.submit(rand_workload(rng, f"w{i}"),
                     priority=SLO if i % 2 == 0 else BEST_EFFORT)
    fleet.remove("w2")
    assert fleet.stats["replans"] == fleet.stats["full_replays"]
    assert fleet.stats["scoped_repairs"] == 0
    cold = cold_fleet(fleet, models, cfg)
    assert fleet_plans_equal(fleet.plan(), cold.plan())


def test_forced_full_mode_never_scopes():
    """repair_mode="full" routes every mutation through the cold replay
    even when the scope would be local."""
    fleet = FleetScheduler(hetero_models(8),
                           replace(SCOPED_CFG, repair_mode="full"),
                           clock=FakeClock())
    rng = np.random.default_rng(3)
    for i in range(4):
        fleet.submit(rand_workload(rng, f"w{i}"))
    assert fleet.stats["scoped_repairs"] == 0
    assert fleet.stats["replans"] == fleet.stats["full_replays"]


def test_scope_merge_unions_and_full_wins():
    a = RepairScope("device-dead", "dev down", workloads=("a", "b"),
                    devices=("d0",))
    b = RepairScope("retry", "retry c", workloads=("b", "c"),
                    devices=("d1",))
    m = a.merge(b)
    assert m.workloads == ("a", "b", "c") and m.devices == ("d0", "d1")
    assert m.kind == "device-dead+retry"
    assert a.merge(RepairScope.full("oops")).kind == "full"
