"""repro.ft primitives: StragglerMonitor EWMA detection/warmup,
HeartbeatTracker timeout math on injected clocks, plan_rescale chip
accounting, and the FakeClock the fault-injection harness runs on."""
import pytest

from repro.ft import HeartbeatTracker, StragglerMonitor, plan_rescale
from repro.ft.inject import FakeClock


# ------------------------------------------------------------------ #
#  StragglerMonitor                                                   #
# ------------------------------------------------------------------ #
def test_straggler_warmup_suppresses_detection():
    mon = StragglerMonitor(factor=3.0, warmup=3, clock=FakeClock())
    # the first observation only seeds the EWMA; through the warmup even
    # a huge outlier must not trip detection
    assert mon.observe(0, 1.0) is False
    assert mon.observe(1, 100.0) is False
    assert mon.observe(2, 1.0) is False
    assert mon.events == []


def test_straggler_detects_after_warmup_and_stamps_clock():
    clock = FakeClock(start=7.0)
    hits = []
    mon = StragglerMonitor(factor=3.0, alpha=0.2, warmup=3,
                           on_straggle=lambda *a: hits.append(a),
                           clock=clock)
    for i in range(4):
        assert mon.observe(i, 1.0) is False
    clock.advance(5.0)
    assert mon.observe(4, 10.0) is True
    assert len(mon.events) == 1 and len(hits) == 1
    assert mon.events[0]["time"] == 12.0        # the injected clock, not wall
    assert mon.events[0]["step"] == 4


def test_straggler_outliers_do_not_poison_ewma():
    mon = StragglerMonitor(factor=3.0, warmup=1, clock=FakeClock())
    for i in range(3):
        mon.observe(i, 1.0)
    ewma_before = mon.ewma
    assert mon.observe(3, 50.0) is True
    # straggling steps must not drag the healthy baseline up
    assert mon.ewma == ewma_before
    # healthy steps keep updating it
    mon.observe(4, 2.0)
    assert mon.ewma == pytest.approx(0.8 * ewma_before + 0.2 * 2.0)


# ------------------------------------------------------------------ #
#  HeartbeatTracker                                                   #
# ------------------------------------------------------------------ #
def test_heartbeat_dead_workers_on_injected_clock():
    clock = FakeClock()
    hb = HeartbeatTracker(timeout_s=5.0, clock=clock)
    hb.beat("w0")
    hb.beat("w1")
    clock.advance(4.0)
    hb.beat("w1")                     # w1 refreshes, w0 goes stale
    assert hb.dead_workers() == []    # 4.0 < timeout for both
    clock.advance(2.0)                # w0 at 6.0, w1 at 2.0
    assert hb.dead_workers() == ["w0"]
    clock.advance(4.0)                # w1 at 6.0 too
    assert sorted(hb.dead_workers()) == ["w0", "w1"]


def test_heartbeat_explicit_now_zero_wins():
    """Regression: ``now or clock()`` treated an explicit ``now=0.0`` as
    unset and silently substituted the current clock."""
    clock = FakeClock(start=100.0)
    hb = HeartbeatTracker(timeout_s=5.0, clock=clock)
    hb.beat("w0", now=0.0)
    assert hb.beats["w0"].last_seen == 0.0
    assert hb.dead_workers(now=0.0) == []
    assert hb.dead_workers() == ["w0"]      # clock says 100.0: stale


def test_heartbeat_forget_stops_tracking():
    clock = FakeClock()
    hb = HeartbeatTracker(timeout_s=1.0, clock=clock)
    hb.beat("w0")
    clock.advance(10.0)
    hb.forget("w0")
    assert hb.dead_workers() == []
    hb.forget("never-seen")                  # idempotent no-op


# ------------------------------------------------------------------ #
#  plan_rescale                                                       #
# ------------------------------------------------------------------ #
def test_plan_rescale_sheds_data_axis():
    plan = plan_rescale({"data": 4, "model": 2}, lost_chips=4,
                        global_batch=256, num_microbatches=4,
                        current_step=1234)
    assert plan.new_shape == {"data": 2, "model": 2}
    assert plan.new_chip_count == 4
    # global batch is preserved via more gradient accumulation
    assert plan.new_global_batch == 256
    assert plan.new_microbatches == 8
    assert plan.restart_step == 1234
    assert plan.lr_scale == 1.0


def test_plan_rescale_pod_fallback_when_data_exhausted():
    plan = plan_rescale({"pod": 2, "data": 1, "model": 4}, lost_chips=1,
                        global_batch=128, num_microbatches=2,
                        current_step=7)
    assert plan.new_shape == {"pod": 1, "data": 1, "model": 4}
    assert plan.new_chip_count == 4
    assert plan.new_microbatches == 4


def test_plan_rescale_no_loss_is_identity():
    plan = plan_rescale({"data": 4, "model": 2}, lost_chips=0,
                        global_batch=64, num_microbatches=2,
                        current_step=0)
    assert plan.new_shape == {"data": 4, "model": 2}
    assert plan.new_microbatches == 2


# ------------------------------------------------------------------ #
#  FakeClock                                                          #
# ------------------------------------------------------------------ #
def test_fake_clock_is_monotonic():
    clock = FakeClock(start=1.5)
    assert clock() == 1.5
    assert clock.advance(0.5) == 2.0
    assert clock() == 2.0
    with pytest.raises(ValueError):
        clock.advance(-0.1)
    assert clock() == 2.0
