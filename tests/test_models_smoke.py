"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, assert output shapes + finiteness; decoder
archs also run prefill + 2 decode steps and check prefill/decode parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, supports_shape
from repro.configs.registry import ASSIGNED, get_config, list_archs, tiny_config
from repro.models import build_model

ARCHS = list_archs(assigned_only=True)


def make_batch(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(k1, (B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_vision_tokens, cfg.d_vision),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = tiny_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, aux = jax.jit(m.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    logits, _ = jax.jit(m.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder])
def test_prefill_decode_parity(arch):
    """Decoding token t+1 after prefill[0:t] must match full forward."""
    cfg = tiny_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = make_batch(cfg, B=B, S=S)
    max_len = S + 8

    full_logits, _ = jax.jit(m.forward)(params, batch)

    pre_batch = {k: (v[:, :S - 1] if k in ("tokens", "labels") else v)
                 for k, v in batch.items()}
    logits_p, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len))(params, pre_batch)
    # prefill last-token logits == forward logits at position S-2
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full_logits[:, S - 2], np.float32), rtol=0.15, atol=0.3)

    logits_d, cache = jax.jit(lambda p, t, c: m.decode_step(p, t, c, S - 1))(
        params, batch["tokens"][:, S - 1:S], cache)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full_logits[:, S - 1], np.float32), rtol=0.15, atol=0.3)


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_flow(arch):
    cfg = tiny_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=1, S=16)
    grads = jax.jit(jax.grad(lambda p: m.loss_fn(p, batch)[0]))(params)
    flat, _ = jax.tree.flatten(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert any(n > 0 for n in norms), f"{arch}: all-zero grads"


def test_applicability_matrix():
    cells = []
    for cfg in ASSIGNED:
        for sname, shape in SHAPES.items():
            if supports_shape(cfg, shape):
                cells.append((cfg.name, sname))
    assert len(cells) == 32
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("falcon-mamba-7b", "long_500k") in cells
    assert ("gemma3-4b", "long_500k") in cells
    assert ("llama3-405b", "long_500k") not in cells


def test_param_counts_match_paper_scale():
    """Analytic param counts are in the advertised ballpark."""
    expect = {
        "llama3-405b": (380e9, 430e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "gemma-2b": (2.0e9, 3.3e9),
        "qwen3-1.7b": (1.2e9, 2.4e9),
        "gemma3-4b": (3.0e9, 5.0e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        # NOTE: the assigned spec (48L x 64e x d_ff 1408) arithmetically
        # yields ~28.5B total; the "16b" in the name is the marketing label
        # of the original (27L) model. We follow the assigned spec.
        "moonshot-v1-16b-a3b": (26e9, 31e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "hubert-xlarge": (0.8e9, 1.2e9),
        "llama-3.2-vision-90b": (80e9, 95e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: n_params {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]B"
