"""repro.sim: deterministic trace generation, the closed serving loop,
and the SLO-attainment metrics — the same-seed → same-report contract
the bench_trace CI gate relies on."""
import numpy as np
import pytest

from repro.core import BEST_EFFORT, SLO, TPU_V5E
from repro.sim import (RequestRecord, SimConfig, Simulator, Trace,
                       TraceConfig, generate_trace)

SMALL = TraceConfig(seed=3, duration=60.0, n_tenants=8, n_bursts=1)


def small_devices(n=6):
    return {f"dev{i}": TPU_V5E for i in range(n)}


# ------------------------------------------------------------------ #
#  trace generation determinism                                       #
# ------------------------------------------------------------------ #
def events_key(trace):
    return [(e.t, e.kind, {k: v for k, v in e.payload.items()
                           if k != "workload"})
            for e in trace.events]


def test_same_seed_same_trace_bit_for_bit():
    a, b = generate_trace(SMALL), generate_trace(SMALL)
    assert events_key(a) == events_key(b)
    assert set(a.tenants) == set(b.tenants)
    for name in a.tenants:
        ta, tb = a.tenants[name], b.tenants[name]
        assert (ta.arch, ta.priority, ta.tbt_base, ta.tbt_slo,
                ta.arrival, ta.depart) == \
               (tb.arch, tb.priority, tb.tbt_base, tb.tbt_slo,
                tb.arrival, tb.depart)


def test_explicit_generator_is_the_single_rng_source():
    # passing the rng explicitly must reproduce the seed-named default —
    # proof there is no hidden module-level RNG in the pipeline
    a = generate_trace(SMALL)
    b = generate_trace(SMALL, rng=np.random.default_rng(SMALL.seed))
    assert events_key(a) == events_key(b)


def test_different_seed_different_trace():
    a = generate_trace(SMALL)
    b = generate_trace(TraceConfig(**{**SMALL.__dict__, "seed": 4}))
    assert events_key(a) != events_key(b)


def test_trace_shape():
    tr = generate_trace(SMALL)
    assert isinstance(tr, Trace)
    n_storm = sum(1 for t in tr.tenants.values() if t.arrival == 0.0)
    assert n_storm >= int(SMALL.n_tenants * SMALL.storm_fraction)
    assert tr.n_requests > 0
    assert all(e.t <= tr.duration for e in tr.events)
    assert tr.tenants_of(SLO) and tr.tenants_of(BEST_EFFORT)
    # requests only ever name known tenants, inside their lifetime
    for e in tr.events:
        if e.kind != "request":
            continue
        spec = tr.tenants[e.payload["tenant"]]
        assert spec.arrival <= e.t
        assert spec.depart is None or e.t < spec.depart
        assert SMALL.min_tokens <= e.payload["n_tokens"] <= SMALL.max_tokens


def test_churn_departs_and_replaces_best_effort():
    cfg = TraceConfig(seed=1, duration=80.0, n_tenants=12,
                      slo_fraction=0.5, churn_fraction=0.5)
    tr = generate_trace(cfg)
    departs = [e for e in tr.events if e.kind == "depart"]
    assert departs
    for e in departs:
        assert tr.tenants[e.payload["name"]].priority == BEST_EFFORT
    assert len(tr.tenants) == cfg.n_tenants + len(departs)


# ------------------------------------------------------------------ #
#  simulator closed loop                                              #
# ------------------------------------------------------------------ #
def test_same_seed_same_report_bit_for_bit():
    r1 = Simulator(generate_trace(SMALL), small_devices()).run()
    r2 = Simulator(generate_trace(SMALL), small_devices()).run()
    assert r1 == r2


def test_simulator_serves_and_reports():
    rep = Simulator(generate_trace(SMALL), small_devices()).run()
    assert rep["fleet"]["event_loop_errors"] == 0
    assert rep["requests"]["total"] == generate_trace(SMALL).n_requests
    assert rep["requests"]["completed"] > 0
    assert rep["goodput"]["tokens_per_s"] > 0
    assert 0.0 <= rep["slo"]["overall"]["attainment"] <= 1.0
    assert set(rep["devices"]["utilization"]) == set(small_devices())


def test_kill_mid_trace_detected_and_survived():
    cfg = TraceConfig(**{**SMALL.__dict__, "kills": ((30.0, "dev2"),)})
    rep = Simulator(generate_trace(cfg), small_devices()).run()
    assert rep["fleet"]["device_deaths"] == 1
    assert rep["devices"]["states"]["dev2"] == "dead"
    assert rep["fleet"]["event_loop_errors"] == 0
    assert rep["requests"]["completed"] > 0


def test_depart_cancels_outstanding_requests():
    cfg = TraceConfig(seed=9, duration=80.0, n_tenants=10,
                      churn_fraction=1.0, slo_fraction=0.2)
    tr = generate_trace(cfg)
    assert any(e.kind == "depart" for e in tr.events)
    rep = Simulator(tr, small_devices()).run()
    # canceled requests never count against attainment
    res = rep["slo"]["overall"]
    assert res["resolved"] + rep["requests"]["canceled"] <= \
        rep["requests"]["total"]
    assert rep["fleet"]["event_loop_errors"] == 0


def test_storm_admitted_in_one_replay():
    tr = generate_trace(SMALL)
    n_storm = sum(1 for t in tr.tenants.values() if t.arrival == 0.0)
    assert n_storm > 1
    sim = Simulator(tr, small_devices())
    sim.run()
    storm_decisions = [d for d in sim.fleet.decisions
                       if "arrival storm" in d.reason]
    assert storm_decisions, "t=0 storm must go through submit_many"


def test_unplaced_tenants_age_not_served():
    # 1 device, k=3 slots, 8 tenants: most stay queued and their
    # requests must resolve as misses (or stay censored), not crash
    rep = Simulator(generate_trace(SMALL), small_devices(1)).run()
    assert rep["fleet"]["event_loop_errors"] == 0
    assert rep["slo"]["overall"]["missed"] > 0


# ------------------------------------------------------------------ #
#  metrics                                                            #
# ------------------------------------------------------------------ #
def test_request_record_deadline_and_slo():
    r = RequestRecord(tenant="t", req_id=0, arrival=10.0, n_tokens=100,
                      priority=SLO, tbt_slo=0.01, slack=2.0)
    assert r.deadline == pytest.approx(13.0)
    assert r.met_slo(now=12.0) is None          # censored
    assert r.met_slo(now=14.0) is False         # deadline passed, unfinished
    r.finish = 12.5
    assert r.met_slo(now=14.0) is True
    assert r.latency == pytest.approx(2.5)
    assert r.observed_tbt == pytest.approx(0.025)
    r.canceled = True
    assert r.met_slo(now=99.0) is None          # canceled never resolves


def test_sim_config_defaults():
    s = SimConfig()
    assert s.tick_dt > 0 and s.settle >= 0
