"""Equivalence of the vectorized batch estimator/planner with the scalar
seed implementation (kept verbatim in benchmarks/_seed_reference.py).

Property-style randomized coverage (fixed seeds, no hypothesis needed):
  * estimate() (wrapper) vs estimate_batch() — identical by construction,
    asserted anyway at 1e-9 across mixed-size batches;
  * both vs the SEED pure-Python estimator at 1e-9, including slot
    fractions, cache-thrash cliffs, and the smem equal-throttle branch;
  * the incremental O(n^2) planner vs the seed O(n^3) planner: identical
    Plan (same placements in order, slowdowns/gains at 1e-9);
  * batched sensitivity vs the seed per-scenario sweep.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
import _seed_reference as seed  # noqa: E402
# shared with the benchmark so oracle tests and perf numbers fuzz the
# same input distribution (single source of truth for the generators)
from bench_planner import (assert_plans_equal, random_profile,  # noqa: E402
                           random_workloads)

from repro.core import (H100, TPU_V5E, KernelProfile,  # noqa: E402
                        estimate, estimate_batch, plan_colocation,
                        sensitivity)
from repro.core.resources import RESOURCE_AXES  # noqa: E402
from repro.core.scheduler import evaluate_pair, evaluate_pair_partitioned  # noqa: E402

TOL = 1e-9


def random_fraction_scenarios(rng, dev, n, max_kernels=4, with_fractions=True):
    """Scenario + slot-fraction batches (distinct from bench_planner's
    plain random_scenarios). Continuous random draws — branch decisions
    (argmax axis, theta prefix) are almost surely untied, so seed/batch
    rounding differences cannot flip them."""
    scenarios, fractions = [], []
    for s in range(n):
        k = int(rng.integers(1, max_kernels + 1))
        sc = [random_profile(rng, f"s{s}k{i}", dev, zero_axes=True,
                             smem_heavy=rng.random() < 0.25,
                             cache_heavy=rng.random() < 0.25)
              for i in range(k)]
        sf = None
        if with_fractions and rng.random() < 0.4:
            sf = {p.name: float(rng.uniform(0.1, 1.0)) for p in sc
                  if rng.random() < 0.7}
        scenarios.append(sc)
        fractions.append(sf)
    return scenarios, fractions


def assert_results_equal(got, want, tol=TOL):
    assert set(got.slowdowns) == set(want.slowdowns)
    for n in want.slowdowns:
        assert got.slowdowns[n] == pytest.approx(want.slowdowns[n],
                                                 rel=tol, abs=tol), n
        assert got.speeds[n] == pytest.approx(want.speeds[n],
                                              rel=tol, abs=tol), n
        assert got.bottleneck[n] == want.bottleneck[n], n
    for r in want.axis_load:
        assert got.axis_load[r] == pytest.approx(want.axis_load[r],
                                                 rel=tol, abs=tol), r
    assert got.feasible_slots == want.feasible_slots


@pytest.mark.parametrize("dev", [TPU_V5E, H100], ids=lambda d: d.name)
def test_estimate_matches_seed_randomized(dev):
    rng = np.random.default_rng(0)
    scenarios, fractions = random_fraction_scenarios(rng, dev, n=150)
    for sc, sf in zip(scenarios, fractions):
        got = estimate(sc, dev, sf)
        want = seed.estimate(sc, dev, sf)
        assert_results_equal(got, want)


@pytest.mark.parametrize("dev", [TPU_V5E, H100], ids=lambda d: d.name)
def test_estimate_batch_matches_looped_estimate(dev):
    """Batching mixed-size scenarios together must not perturb any single
    solve (padding is inert)."""
    rng = np.random.default_rng(1)
    scenarios, fractions = random_fraction_scenarios(rng, dev, n=120, max_kernels=5)
    batched = estimate_batch(scenarios, dev, fractions)
    for sc, sf, got in zip(scenarios, fractions, batched):
        assert_results_equal(got, estimate(sc, dev, sf), tol=0.0)


def test_smem_equal_throttle_branch():
    """Two smem-saturating kernels + a light GEMM: the seed's equal-
    throttle branch must be reproduced exactly, including the freeze
    bookkeeping that the later axes see."""
    rng = np.random.default_rng(2)
    smem_hits = 0
    for trial in range(40):
        sc = [random_profile(rng, f"t{trial}k{i}", H100, smem_heavy=True)
              for i in range(3)]
        got, want = estimate(sc, H100), seed.estimate(sc, H100)
        assert_results_equal(got, want)
        smem_hits += "smem" in set(want.bottleneck.values())
    # another axis may legitimately freeze first in some trials, but the
    # equal-throttle branch must be exercised by the bulk of them
    assert smem_hits >= 20, smem_hits


def test_cache_thrash_cliff():
    """Crossing the combined-working-set cliff flips the colocated cache
    share to zero — both paths must agree on both sides of the cliff."""
    for mb in (4, 8, 16, 26, 48, 80):
        ws = 2 * mb * 1e6
        d = {r: 0.0 for r in RESOURCE_AXES}
        d.update(hbm=0.9 * H100.hbm_bw, l2=0.4 * H100.l2_bw,
                 issue=0.2 * H100.issue_rate)
        sc = [KernelProfile(n, demand=dict(d), duration=1.0,
                            cache_working_set=ws, cache_hit_fraction=0.95)
              for n in ("a", "b")]
        assert_results_equal(estimate(sc, H100), seed.estimate(sc, H100))


def test_slot_fraction_branch():
    k = KernelProfile("c", demand={**{r: 0.0 for r in RESOURCE_AXES},
                                   "issue": 0.99 * H100.issue_rate,
                                   "vpu": 0.5 * H100.vpu_flops},
                      duration=1.0)
    for f in (0.0625, 0.25, 0.5, 1.0):
        got = estimate([k], H100, {"c": f})
        want = seed.estimate([k], H100, {"c": f})
        assert_results_equal(got, want)


@pytest.mark.parametrize("allow_partition", [True, False])
def test_planner_matches_seed(allow_partition):
    rng = np.random.default_rng(3)
    works = random_workloads(rng, 12, TPU_V5E)
    got = plan_colocation(works, TPU_V5E, allow_partition)
    want = seed.plan_colocation(works, TPU_V5E, allow_partition)
    assert_plans_equal(got, want)


def test_pair_evaluation_matches_seed():
    rng = np.random.default_rng(4)
    works = random_workloads(rng, 6, TPU_V5E)
    for i in range(len(works)):
        for j in range(i + 1, len(works)):
            for fn_new, fn_seed in ((evaluate_pair, seed.evaluate_pair),
                                    (evaluate_pair_partitioned,
                                     seed.evaluate_pair_partitioned)):
                g = fn_new(works[i], works[j], TPU_V5E)
                w = fn_seed(works[i], works[j], TPU_V5E)
                assert g.workloads == w.workloads
                assert g.meets_slo == w.meets_slo
                assert g.slot_fraction == w.slot_fraction
                assert g.throughput_gain == pytest.approx(
                    w.throughput_gain, rel=TOL, abs=TOL)


def test_sensitivity_matches_seed_loop():
    """The batched (axes x lambda) fingerprint equals the seed's one-
    scenario-at-a-time sweep."""
    from repro.core.sensitivity import stressor
    rng = np.random.default_rng(5)
    k = random_profile(rng, "probe", TPU_V5E)
    rep = sensitivity(k, TPU_V5E)
    for ai, axis in enumerate(RESOURCE_AXES):
        for li, lam in enumerate(rep.lambdas):
            want = seed.estimate([k, stressor(axis, lam, TPU_V5E)],
                                 TPU_V5E).slowdowns["probe"]
            assert rep.curves[axis][li] == pytest.approx(want, rel=TOL,
                                                         abs=TOL)


def test_duplicate_kernel_names_rejected():
    """The seed silently collapsed same-named kernels into one (name-keyed
    dicts); the batch path refuses them instead — the positional
    `solve_batch` API is the supported route for same-profile colocation,
    and there both instances genuinely contend."""
    from repro.core.estimator import solve_batch
    from repro.core.profile import ProfileMatrix
    k = KernelProfile("dup", demand={**{r: 0.0 for r in RESOURCE_AXES},
                                     "mxu": 0.9 * TPU_V5E.mxu_flops},
                      duration=1.0)
    with pytest.raises(ValueError, match="duplicate kernel names"):
        estimate([k, k], TPU_V5E)
    pm = ProfileMatrix.from_profiles([k])
    br = solve_batch(pm, np.array([[0, 0]]), TPU_V5E)
    # both instances throttle to the fair share: speed 0.5/0.9 each
    assert br.slowdowns[0, 0] == pytest.approx(1.8, rel=1e-6)
    assert br.slowdowns[0, 1] == pytest.approx(br.slowdowns[0, 0])


def test_plan_total_gain_uses_member_gains():
    """Regression for the seed bug: total_gain counted workloads per
    device slot instead of the placements' predicted gains."""
    from repro.core.scheduler import Placement, Plan
    p1 = Placement(["a", "b"], {}, {"a": 1.1, "b": 1.2}, True, 1.8)
    p2 = Placement(["c", "d"], {}, {"c": 1.0, "d": 1.0}, True, 1.4)
    plan = Plan([p1, p2], ["e"])
    assert plan.total_gain == pytest.approx((1.8 + 1.4 + 1.0) / 3)
    assert Plan([], []).total_gain == 1.0
