"""Online ColocationScheduler: incremental == cold, k=2 == legacy pairing
(and the seed planner), k=3 oracle vs direct estimate() calls, O(n)
arrival pricing, and the deprecation shims forwarding identically."""
import sys
import warnings
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
import _seed_reference as seed  # noqa: E402
from bench_planner import assert_plans_equal, random_workloads  # noqa: E402

from repro.core import (LEGACY_SEARCH, TPU_V5E, ColocationScheduler,  # noqa: E402
                        FractionSearchConfig, KernelProfile,
                        WorkloadProfile, estimate, evaluate_group,
                        evaluate_group_partitioned, evaluate_pair,
                        evaluate_pair_partitioned, plan_colocation)
from repro.core.resources import RESOURCE_AXES  # noqa: E402
from repro.core.scheduler import _PARTITION_FRACTIONS  # noqa: E402

TOL = 1e-9


def cold(works, dev=TPU_V5E, k=2, allow_partition=True, search=None):
    s = ColocationScheduler(dev, max_group_size=k,
                            allow_partition=allow_partition,
                            fraction_search=search)
    for w in works:
        s.submit(w)
    return s


# ------------------------------------------------------------------ #
#  k=2 + LEGACY_SEARCH reproduces the one-shot pairing exactly        #
#  (the default search explores a richer fraction space — pinned      #
#  separately to never place worse than the seed)                     #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("allow_partition", [True, False])
def test_k2_cold_scheduler_matches_seed_planner(allow_partition):
    rng = np.random.default_rng(3)
    works = random_workloads(rng, 12, TPU_V5E)
    got = cold(works, allow_partition=allow_partition,
               search=LEGACY_SEARCH).plan()
    want = seed.plan_colocation(works, TPU_V5E, allow_partition)
    assert_plans_equal(got, want)


def test_default_search_never_places_worse_than_seed():
    """The default (finer + refined) fraction search must dominate the
    seed's fixed grid: every placement feasible, total gain >= the seed
    planner's on the same pool (this draw places partitioned pairs)."""
    rng = np.random.default_rng(3)
    works = random_workloads(rng, 12, TPU_V5E)
    got = cold(works).plan()
    want = seed.plan_colocation(works, TPU_V5E, True)
    assert all(p.meets_slo for p in got.placements)
    seed_gain = (sum(p.throughput_gain for p in want.placements)
                 + len(want.solo)) / max(
        len(want.placements) + len(want.solo), 1)
    assert got.total_gain >= seed_gain - TOL


def test_plan_colocation_shim_warns_and_forwards():
    rng = np.random.default_rng(4)
    works = random_workloads(rng, 10, TPU_V5E)
    with pytest.warns(DeprecationWarning, match="plan_colocation"):
        got = plan_colocation(works, TPU_V5E)
    assert_plans_equal(got, cold(works, search=LEGACY_SEARCH).plan())


def test_evaluate_pair_shims_warn_and_forward():
    rng = np.random.default_rng(5)
    a, b = random_workloads(rng, 2, TPU_V5E)
    with pytest.warns(DeprecationWarning, match="evaluate_pair"):
        got = evaluate_pair(a, b, TPU_V5E)
    want = evaluate_group((a, b), TPU_V5E)
    sref = seed.evaluate_pair(a, b, TPU_V5E)
    for other in (want, sref):
        assert got.workloads == other.workloads
        assert got.meets_slo == other.meets_slo
        assert got.throughput_gain == pytest.approx(other.throughput_gain,
                                                    rel=TOL, abs=TOL)
        for n in other.predicted_slowdown:
            assert got.predicted_slowdown[n] == pytest.approx(
                other.predicted_slowdown[n], rel=TOL, abs=TOL)

    with pytest.warns(DeprecationWarning, match="evaluate_pair_partitioned"):
        gp = evaluate_pair_partitioned(a, b, TPU_V5E)
    # the shim forwards the legacy first-member grid — bit-equal to both
    # the explicit-fractions path and the seed implementation
    wp = evaluate_group_partitioned((a, b), TPU_V5E, _PARTITION_FRACTIONS)
    sp = seed.evaluate_pair_partitioned(a, b, TPU_V5E)
    for other in (wp, sp):
        assert gp.slot_fraction == other.slot_fraction
        assert gp.throughput_gain == pytest.approx(other.throughput_gain,
                                                   rel=TOL, abs=TOL)


# ------------------------------------------------------------------ #
#  Incremental replanning == cold plan on the surviving set           #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("k", [2, 3])
def test_incremental_trace_matches_cold(k):
    rng = np.random.default_rng(11)
    pool = random_workloads(rng, 40, TPU_V5E)
    sched = ColocationScheduler(TPU_V5E, max_group_size=k)
    resident = []
    fresh = list(pool)
    for event in range(24):
        if resident and rng.random() < 0.4:
            victim = resident.pop(int(rng.integers(len(resident))))
            sched.remove(victim.name)
        else:
            w = fresh.pop()
            resident.append(w)
            sched.submit(w)
        got = sched.plan()
        want = cold(resident, k=k).plan()
        assert_plans_equal(got, want)


def test_arrival_prices_one_row_departure_prices_nothing():
    rng = np.random.default_rng(12)
    works = random_workloads(rng, 24, TPU_V5E)
    sched = cold(works[:-1])
    sched.plan()
    cold_scen = sched.stats["scenarios_solved"]
    n = len(works) - 1

    sched.submit(works[-1])
    sched.plan()
    arrival_scen = sched.stats["scenarios_solved"] - cold_scen
    # the new row: per pair, the arrival's kernels probe the resident's
    # rep and vice versa, plus the fraction search's coarse grid
    # (steps-1 vectors at k=2) and refinement levels for every
    # SLO-failing pair — a larger constant than the legacy 3-point
    # grid, but still linear in n, far below the O(n^2) cold price.
    # The constant follows the ACTIVE search config (the jax backend's
    # denser default grid prices more candidates per pair).
    per_pair = 5 * (sched.search.steps_for(2) - 1 + sched.search.refine_levels)
    assert 0 < arrival_scen <= per_pair * (n + 1)
    assert arrival_scen < cold_scen / 4

    before = sched.stats["scenarios_solved"]
    sched.remove(works[0].name)
    sched.plan()
    assert sched.stats["scenarios_solved"] == before


def test_departure_releases_group_survivors():
    mk = lambda name: WorkloadProfile(
        name, (KernelProfile(name + ":k", demand={
            **{r: 0.0 for r in RESOURCE_AXES},
            "hbm": 0.3 * TPU_V5E.capacity("hbm")}, duration=1.0),),
        slo_slowdown=2.0)
    works = [mk(f"w{i}") for i in range(4)]
    sched = cold(works)
    plan = sched.plan()
    assert len(plan.placements) == 2
    partner = next(p for p in plan.placements if "w0" in p.workloads)
    survivor = next(n for n in partner.workloads if n != "w0")
    sched.remove("w0")
    replan = sched.plan()
    placed = {n for p in replan.placements for n in p.workloads}
    # the widowed survivor is back in the pool: re-paired or solo
    assert survivor in placed | set(replan.solo)
    assert "w0" not in placed | set(replan.solo)


def test_remove_unknown_raises_and_leaves_state_intact():
    """Removing a name never submitted (or already removed) raises a
    clear KeyError BEFORE any mutation: the pool, the pricing cache, and
    online==cold are exactly what they were."""
    rng = np.random.default_rng(21)
    works = random_workloads(rng, 8, TPU_V5E)
    sched = cold(works)
    sched.plan()
    cache_before = (len(sched._pair), len(sched._group))
    stats_before = dict(sched.stats)
    with pytest.raises(KeyError):
        sched.remove("never-submitted")
    sched.remove(works[0].name)
    with pytest.raises(KeyError):
        sched.remove(works[0].name)          # double-remove: same error
    assert len(sched._pair) <= cache_before[0]
    assert len(sched._group) <= cache_before[1]
    assert sched.stats["departures"] == stats_before["departures"] + 1
    assert_plans_equal(sched.plan(), cold(works[1:]).plan())


def test_double_submit_identical_profile_keeps_online_equal_cold():
    """Re-submitting the SAME profile is the documented no-op-shaped
    path (last-profile-wins): prices for that workload are invalidated
    and re-derived, and the plan still equals a cold scheduler fed each
    workload once."""
    rng = np.random.default_rng(22)
    works = random_workloads(rng, 8, TPU_V5E)
    sched = cold(works)
    sched.plan()
    sched.submit(works[3])                   # exact duplicate
    sched.submit(works[3])                   # and again
    assert len(sched) == len(works)
    assert_plans_equal(sched.plan(), cold(works).plan())


def test_error_paths_then_churn_keep_online_equal_cold():
    """After exercising every error/edge path — unknown remove, double
    remove, duplicate submit — continued churn must still replay to the
    cold plan (the pricing cache was never corrupted)."""
    rng = np.random.default_rng(23)
    works = random_workloads(rng, 10, TPU_V5E)
    sched = cold(works[:8])
    with pytest.raises(KeyError):
        sched.remove(works[9].name)          # not yet submitted
    sched.submit(works[5])                   # duplicate
    sched.remove(works[2].name)
    with pytest.raises(KeyError):
        sched.remove(works[2].name)          # double remove
    sched.submit(works[8])
    sched.submit(works[9])
    pool = [w for w in works if w.name != works[2].name]
    assert_plans_equal(sched.plan(), cold(pool).plan())


def test_resubmit_updates_profile_in_place():
    rng = np.random.default_rng(13)
    works = random_workloads(rng, 8, TPU_V5E)
    sched = cold(works)
    sched.plan()
    # re-submit w3 with a different profile: the plan must equal a cold
    # plan over the updated pool in the original arrival order
    updated = random_workloads(np.random.default_rng(99), 8, TPU_V5E)[3]
    updated = WorkloadProfile(works[3].name, updated.kernels,
                              updated.slo_slowdown)
    sched.submit(updated)
    new_pool = [updated if w.name == updated.name else w for w in works]
    assert_plans_equal(sched.plan(), cold(new_pool).plan())


# ------------------------------------------------------------------ #
#  k-way placements                                                   #
# ------------------------------------------------------------------ #
def _decode_like(name, hbm=0.28, slo=2.0):
    d = {r: 0.0 for r in RESOURCE_AXES}
    d["hbm"] = hbm * TPU_V5E.capacity("hbm")
    d["issue"] = 0.05 * TPU_V5E.capacity("issue")
    return WorkloadProfile(name, (KernelProfile(name + ":k", demand=d,
                                                duration=1.0),),
                           slo_slowdown=slo)


def test_k3_oracle_against_direct_estimate():
    """A 3-way group's numbers must equal first-principles estimate()
    calls: each member's kernel vs the other members' rep kernels."""
    works = [_decode_like(f"dec{i}") for i in range(3)]
    plan = cold(works, k=3).plan()
    assert len(plan.placements) == 1
    pl = plan.placements[0]
    assert sorted(pl.workloads) == [w.name for w in works]

    reps = {w.name: w.representative_kernel(TPU_V5E) for w in works}
    times = {w.name: w.total_time(TPU_V5E) for w in works}
    expected = {}
    for w in works:
        others = [reps[o.name] for o in works if o.name != w.name]
        r = estimate([w.kernels[0]] + others, TPU_V5E)
        expected[w.name] = r.slowdowns[w.kernels[0].name]
    for n, want in expected.items():
        assert pl.predicted_slowdown[n] == pytest.approx(want, rel=TOL,
                                                         abs=TOL)
    want_gain = sum(times.values()) / max(times[n] * expected[n]
                                          for n in expected)
    assert pl.throughput_gain == pytest.approx(want_gain, rel=TOL, abs=TOL)
    # group pricing == the scalar evaluate_group twin
    oracle = evaluate_group(works, TPU_V5E)
    assert pl.throughput_gain == pytest.approx(oracle.throughput_gain,
                                               rel=TOL, abs=TOL)


def test_k3_beats_k2_on_decode_heavy_mix():
    mix = [_decode_like(f"dec{i}") for i in range(6)]
    gain2 = cold(mix, k=2).plan().total_gain
    gain3 = cold(mix, k=3).plan().total_gain
    assert gain3 > gain2 > 1.0


def test_k3_respects_slo():
    """Growth must stop before any member would violate its SLO."""
    mix = [_decode_like(f"dec{i}", hbm=0.45, slo=1.25) for i in range(4)]
    plan = cold(mix, k=4).plan()
    for pl in plan.placements:
        assert pl.meets_slo
        assert max(pl.predicted_slowdown.values()) <= 1.25 + TOL


def test_max_group_size_validation():
    with pytest.raises(ValueError, match="max_group_size"):
        ColocationScheduler(TPU_V5E, max_group_size=1)
    with pytest.raises(KeyError):
        ColocationScheduler(TPU_V5E).remove("ghost")


# ------------------------------------------------------------------ #
#  place_candidates: the non-mutating per-device probe               #
# ------------------------------------------------------------------ #
def test_place_candidates_matches_evaluate_group_oracle():
    """Every full-share join candidate's gain/slowdowns must equal the
    scalar evaluate_group twin on the same member set at 1e-9."""
    rng = np.random.default_rng(11)
    works = random_workloads(rng, 5, TPU_V5E)
    probe = random_workloads(rng, 7, TPU_V5E)[6]
    s = cold(works, k=3)
    by_name = {w.name: w for w in works}
    by_name[probe.name] = probe
    for p in s.place_candidates(probe):
        if len(p.workloads) == 1 or p.slot_fraction:
            continue            # solo sentinel / partition-rescued join
        want = evaluate_group([by_name[n] for n in p.workloads], TPU_V5E)
        assert abs(p.throughput_gain - want.throughput_gain) <= 1e-9
        assert p.meets_slo == want.meets_slo
        for n in p.workloads:
            assert abs(p.predicted_slowdown[n]
                       - want.predicted_slowdown[n]) <= 1e-9


def test_place_candidates_is_pure_probe():
    """The probe admits nothing: the resident pool, the plan, and the
    caches keyed by the probe's name stay untouched."""
    rng = np.random.default_rng(12)
    works = random_workloads(rng, 4, TPU_V5E)
    probe = random_workloads(rng, 6, TPU_V5E)[5]
    s = cold(works, k=3)
    before_plan = s.plan()
    before = s.snapshot()
    cands = s.place_candidates(probe)
    after = s.snapshot()
    assert probe.name not in s
    assert after["workloads"] == before["workloads"]
    assert after["cached_pairs"] == before["cached_pairs"]
    assert after["cached_groups"] == before["cached_groups"]
    assert_plans_equal(s.plan(), before_plan)
    # sorted by gain descending, solo sentinel always present
    gains = [p.throughput_gain for p in cands]
    assert gains == sorted(gains, reverse=True)
    solo = [p for p in cands if list(p.workloads) == [probe.name]]
    assert len(solo) == 1 and solo[0].meets_slo
    assert solo[0].throughput_gain == 1.0


def test_place_candidates_partition_rescues_failing_join():
    """A join that misses SLO at full share but passes under the slot-
    fraction search must surface as a feasible partitioned candidate;
    with allow_partition=False the same join stays infeasible (visible
    with meets_slo=False, never silently dropped)."""
    from bench_planner import decode_heavy_mix
    d0, d1 = decode_heavy_mix(TPU_V5E, n_decode=2, n_aux=0)
    full = evaluate_group([d0, d1], TPU_V5E)
    assert not full.meets_slo          # the gate mix: pair fails shared
    s = cold([d0], k=2, allow_partition=True)
    join = [p for p in s.place_candidates(d1)
            if set(p.workloads) == {d0.name, d1.name}]
    assert len(join) == 1
    assert join[0].meets_slo and join[0].slot_fraction
    s2 = cold([d0], k=2, allow_partition=False)
    join2 = [p for p in s2.place_candidates(d1)
             if set(p.workloads) == {d0.name, d1.name}]
    assert len(join2) == 1 and not join2[0].meets_slo


def test_place_candidates_resident_name_raises():
    rng = np.random.default_rng(13)
    works = random_workloads(rng, 3, TPU_V5E)
    s = cold(works, k=3)
    with pytest.raises(ValueError):
        s.place_candidates(works[0])
