"""Parity suite for the jax solver backend (ISSUE 8).

The contract: `repro.core.estimator_jax` is a jit-compiled twin of the
NumPy water-filling solver, equal at 1e-9 (rtol AND atol — slowdowns of
excluded-neighbor scenarios legitimately reach ~1e9, where 1e-9 absolute
on a ~1e-16 relative error is unattainable in float64) on every branch
of the model: slot-fraction exclusion, smem equal-throttle, the cache
thrash cliff exactly at the boundary, ragged widths, empty batches.

The random-scenario distributions come from benchmarks/bench_planner.py
(the same generators the oracle tests and the CI bench fuzz), steered
into specific estimator branches via its flags.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))

jax = pytest.importorskip("jax")

from bench_planner import random_profile, random_scenarios  # noqa: E402
from repro.core import (TPU_V5E, DENSE_SEARCH, FractionSearchConfig,  # noqa: E402
                        KernelProfile, Scenario, get_solver_backend,
                        set_solver_backend, solver_backend)
from repro.core import estimator_jax  # noqa: E402
from repro.core.estimator import solve_batch, solve_scenarios  # noqa: E402
from repro.core.profile import ProfileMatrix  # noqa: E402

DEV = TPU_V5E
RTOL = ATOL = 1e-9


def both_backends(fn):
    """Run `fn` under numpy then jax and return both results."""
    r_np = fn()
    with solver_backend("jax"):
        r_jx = fn()
    return r_np, r_jx


def assert_results_equal(r_np, r_jx):
    assert r_np.mask.shape == r_jx.mask.shape
    np.testing.assert_array_equal(r_np.mask, r_jx.mask)
    np.testing.assert_array_equal(r_np.bottleneck, r_jx.bottleneck)
    np.testing.assert_array_equal(r_np.feasible_slots, r_jx.feasible_slots)
    for field in ("speeds", "slowdowns", "axis_load"):
        a, b = getattr(r_np, field), getattr(r_jx, field)
        fin = np.isfinite(a)
        np.testing.assert_array_equal(fin, np.isfinite(b),
                                      err_msg=f"{field}: finiteness differs")
        np.testing.assert_allclose(b[fin], a[fin], rtol=RTOL, atol=ATOL,
                                   err_msg=field)


def pool(rng, n=48):
    """Mixed kernel pool hitting every solver branch: zeroed axes,
    smem-saturating, cache-heavy."""
    return [random_profile(rng, f"k{i}", DEV,
                           zero_axes=(i % 3 == 0),
                           smem_heavy=(i % 5 == 0),
                           cache_heavy=(i % 4 == 0)) for i in range(n)]


@pytest.mark.parametrize("k", [1, 2, 3, 4, 5, 6])
def test_parity_random_widths(k):
    rng = np.random.default_rng(100 + k)
    pm = ProfileMatrix.from_profiles(pool(rng))
    idx = rng.integers(0, len(pm.names), (128, k))
    r_np, r_jx = both_backends(lambda: solve_batch(pm, idx, DEV))
    assert_results_equal(r_np, r_jx)


@pytest.mark.parametrize("k", [2, 3, 4, 6])
def test_parity_slot_fractions_and_exclusion(k):
    """Random simplex fractions, some pushed to (and below) the
    FRACTION_FLOOR exclusion — excluded members must come back speed 0 /
    slowdown +inf on both backends."""
    rng = np.random.default_rng(200 + k)
    pm = ProfileMatrix.from_profiles(pool(rng))
    S = 128
    idx = rng.integers(0, len(pm.names), (S, k))
    frac = rng.random((S, k)) * 0.9 + 0.05
    frac /= frac.sum(1, keepdims=True)
    excl = rng.random((S, k)) < 0.1
    frac = np.where(excl, 1e-7, frac)
    r_np, r_jx = both_backends(lambda: solve_batch(pm, idx, DEV, frac))
    assert np.isinf(r_np.slowdowns[excl]).all()
    assert np.isinf(r_jx.slowdowns[excl]).all()
    assert_results_equal(r_np, r_jx)


def test_parity_smem_worst_axis():
    """Batches built to freeze on the smem equal-throttle branch."""
    rng = np.random.default_rng(7)
    profs = [random_profile(rng, f"s{i}", DEV, smem_heavy=True)
             for i in range(16)]
    pm = ProfileMatrix.from_profiles(profs)
    idx = rng.integers(0, 16, (64, 3))
    r_np, r_jx = both_backends(lambda: solve_batch(pm, idx, DEV))
    # the branch actually fired: some member froze on the smem axis
    from repro.core.estimator import _SMEM
    assert (r_np.bottleneck == _SMEM).any()
    assert_results_equal(r_np, r_jx)


def test_parity_cache_cliff_boundary():
    """total_ws == cache_cap sits exactly ON the thrash cliff (share
    collapses only strictly ABOVE capacity) — the discrete comparison
    must agree between backends at the boundary and on either side."""
    cap = DEV.cache_capacity
    mk = lambda name, ws: KernelProfile(
        name, demand={"hbm": 0.8 * DEV.capacity("hbm")},
        cache_working_set=ws, cache_hit_fraction=0.9)
    bg = KernelProfile("bg", demand={"hbm": 0.4 * DEV.capacity("hbm")})
    scens = [Scenario((mk(f"a{ws}", ws), bg))
             for ws in (0.5 * cap, cap, np.nextafter(cap, np.inf),
                        2.0 * cap)]
    r_np, r_jx = both_backends(lambda: solve_scenarios(scens, DEV))
    assert_results_equal(r_np, r_jx)
    # AT capacity the hits survive (cliff is strictly above); one ulp
    # over, they collapse and the pair saturates hbm
    assert (r_np.slowdowns[1] < r_np.slowdowns[2]).all()
    np.testing.assert_allclose(r_np.slowdowns[0], r_np.slowdowns[1])


def test_parity_empty_and_zero_width():
    r_np, r_jx = both_backends(lambda: solve_scenarios([], DEV))
    assert len(r_np) == len(r_jx) == 0
    empty = [Scenario(()), Scenario(())]
    r_np, r_jx = both_backends(lambda: solve_scenarios(empty, DEV))
    assert r_np.speeds.shape == r_jx.speeds.shape
    assert r_np.feasible_slots.all() and r_jx.feasible_slots.all()


def test_ragged_batch_equals_per_row_solves():
    """Satellite regression: compile_scenarios pads ragged widths to one
    dense (S, K_max) masked batch — results must equal solving each
    scenario on its own, on BOTH backends."""
    rng = np.random.default_rng(11)
    scen_kernels = random_scenarios(rng, 40, DEV)   # widths 2..4, ragged
    scens = [Scenario(tuple(sc)) for sc in scen_kernels]
    widths = {len(sc.members) for sc in scens}
    assert len(widths) > 1, "distribution must actually be ragged"
    for backend in ("numpy", "jax"):
        with solver_backend(backend):
            batched = solve_scenarios(scens, DEV)
            for s, sc in enumerate(scens):
                solo = solve_scenarios([sc], DEV)
                k = len(sc.members)
                np.testing.assert_allclose(
                    batched.slowdowns[s, :k], solo.slowdowns[0],
                    rtol=RTOL, atol=ATOL, err_msg=f"{backend} row {s}")
                assert (batched.bottleneck[s, :k]
                        == solo.bottleneck[0]).all()
                assert batched.feasible_slots[s] == solo.feasible_slots[0]


def test_compiled_ragged_is_dense_with_mask():
    from repro.core import compile_scenarios
    rng = np.random.default_rng(3)
    ps = pool(rng, 8)
    scens = [Scenario(tuple(ps[:2])), Scenario(tuple(ps[:4])),
             Scenario((ps[5],))]
    comp = compile_scenarios(scens)
    assert isinstance(comp.members, np.ndarray)
    assert comp.members.shape == (3, 4)
    assert comp.mask is not None
    assert comp.mask.sum(1).tolist() == [2, 4, 1]


def test_jit_cache_two_shapes_two_traces():
    """Shape discipline: batches land in power-of-two size buckets, so
    two DIFFERENT batch sizes in the same bucket share one trace and a
    second bucket adds exactly one more."""
    rng = np.random.default_rng(5)
    pm = ProfileMatrix.from_profiles(pool(rng, 8))
    # K=7 is unique to this test: the jit cache is process-global, so any
    # (bucket, K) shape another test already solved would be warm here
    with solver_backend("jax"):
        idx = rng.integers(0, 8, (33, 7))
        solve_batch(pm, idx, DEV)                     # bucket 64
        t0 = estimator_jax.trace_count()
        solve_batch(pm, idx[:40], DEV)                # still bucket 64
        solve_batch(pm, idx[:64], DEV)                # still bucket 64
        assert estimator_jax.trace_count() == t0
        solve_batch(pm, np.vstack([idx, idx]), DEV)   # bucket 128: 1 trace
        assert estimator_jax.trace_count() == t0 + 1
        solve_batch(pm, np.vstack([idx, idx]), DEV)   # warm: no new trace
        assert estimator_jax.trace_count() == t0 + 1


def test_pallas_share_kernel_matches_ref():
    """The Pallas cache-share kernel (interpret mode on CPU) computes
    exactly the jnp fallback expression, including the cliff boundary."""
    from repro.kernels.cache_share import cache_share_pallas
    import jax.numpy as jnp
    rng = np.random.default_rng(9)
    cap = DEV.cache_capacity
    ws = rng.random((37, 3)) * 2.0 * cap
    ws[rng.random((37, 3)) < 0.3] = 0.0
    ws[0] = [cap / 2, cap / 2, 0.0]                  # total == cap exactly
    present = rng.random((37, 3)) < 0.9
    ws = np.where(present, ws, 0.0)
    ref = estimator_jax.cache_share_ref(jnp.asarray(ws),
                                        jnp.asarray(present), cap)
    got = cache_share_pallas(jnp.asarray(ws), jnp.asarray(present), cap,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_backend_switch_and_env():
    assert get_solver_backend() in ("numpy", "jax")
    prev = set_solver_backend("jax")
    try:
        assert get_solver_backend() == "jax"
        with solver_backend("numpy"):
            assert get_solver_backend() == "numpy"
        assert get_solver_backend() == "jax"
        with pytest.raises(ValueError):
            set_solver_backend("tpu")
    finally:
        set_solver_backend(prev)


def test_default_search_config_follows_backend():
    with solver_backend("numpy"):
        assert FractionSearchConfig.default() == FractionSearchConfig()
    with solver_backend("jax"):
        assert FractionSearchConfig.default() == DENSE_SEARCH
    # the dense grid embeds the standard one: every 8-step coarse point
    # (and its level-1 refinement points, which land on 16ths) is a
    # 16-step point, so the dense search can never select a worse gain
    from repro.core import simplex_candidates
    coarse8 = set(simplex_candidates(2, 8))
    coarse16 = set(simplex_candidates(2, 16))
    assert coarse8 <= coarse16


def test_warmup_compiles_each_shape_once_shared_across_models():
    """warmup() AOT-compiles each requested (bucket, K) shape exactly
    once; re-warming is free, a different device model hits the same
    traces (capacities are traced operands), and a real solve of a
    warmed shape adds no trace."""
    from repro.core import TPU_V5P
    # K=11 is unique to this test (the jit cache is process-global)
    with solver_backend("jax"):
        assert estimator_jax.warmup(DEV, ks=(11,)) == 1
        assert estimator_jax.warmup(DEV, ks=(11,)) == 0
        assert estimator_jax.warmup(TPU_V5P, ks=(11,)) == 0
        rng = np.random.default_rng(21)
        pm = ProfileMatrix.from_profiles(pool(rng, 12))
        t0 = estimator_jax.trace_count()
        solve_batch(pm, rng.integers(0, 12, (5, 11)), DEV)  # bucket 8
        assert estimator_jax.trace_count() == t0


def test_scheduler_warmup_flag_precompiles_group_widths():
    """ColocationScheduler(warmup=True) warms every group width up to
    max_group_size at construction, so the first plan's solves of any
    warmed shape compile nothing."""
    from repro.core import ColocationScheduler
    # max_group_size=12 -> K=12 is unique to this test
    with solver_backend("jax"):
        ColocationScheduler(DEV, max_group_size=12, warmup=True)
        rng = np.random.default_rng(23)
        ps = pool(rng, 12)
        t0 = estimator_jax.trace_count()
        solve_scenarios([Scenario(tuple(ps))], DEV)   # width 12, bucket 8
        assert estimator_jax.trace_count() == t0


def test_warmup_solver_is_noop_on_numpy_backend():
    """The backend-level switch: warmup_solver never imports or traces
    anything when the numpy solver is active."""
    from repro.core import warmup_solver
    with solver_backend("numpy"):
        assert warmup_solver(DEV, ks=(2, 3)) == 0
