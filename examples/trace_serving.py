"""Trace-driven serving walkthrough: generate a diurnal+burst multi-
tenant trace, replay it through the `repro.sim` closed loop with a
mid-trace device kill, and read the SLO-attainment report.

The pipeline, end to end:

  1. `TraceConfig` + `generate_trace` — a seeded, replayable tape of
     tenant arrivals (half in a t=0 storm), best-effort churn,
     per-tenant Poisson request streams shaped by a day-curve and
     fleet-wide burst windows, plus scripted faults;
  2. `Simulator` — a virtual-clock loop feeding those events into
     `FleetScheduler.tick()` and serving each placed tenant's requests
     at its interference-inflated rate (tbt_base x the placement's
     predicted slowdown from `solve_scenarios`);
  3. the report — per-class SLO attainment (TTFT-slack + per-token
     deadlines), observed/service TBT percentiles, goodput, and the
     fleet's eviction/migration/replan counters.

Run:  PYTHONPATH=src python examples/trace_serving.py
"""
from repro.core import TPU_V5E
from repro.sim import Simulator, TraceConfig, generate_trace


def main():
    cfg = TraceConfig(
        seed=42,
        duration=120.0,          # virtual seconds of traffic
        n_tenants=16,            # half SLO-class, half best-effort
        n_bursts=2,              # fleet-wide 4x burst windows
        churn_fraction=0.25,     # best-effort tenants depart + replace
        kills=((60.0, "dev2"),)  # dev2's host dies mid-trace
    )
    trace = generate_trace(cfg)
    print("== trace ==")
    print(f"  {trace.summary()}")
    slo = trace.tenants_of("slo")
    print(f"  example SLO tenant: {slo[0].name} arch={slo[0].arch} "
          f"tbt_base={slo[0].tbt_base * 1e3:.2f}ms "
          f"tbt_slo={slo[0].tbt_slo * 1e3:.2f}ms/token")

    sim = Simulator(trace, {f"dev{i}": TPU_V5E for i in range(6)})
    report = sim.run()

    print("\n== serving report ==")
    req = report["requests"]
    print(f"  requests: {req['total']} total, {req['completed']} "
          f"completed, {req['canceled']} canceled (churned tenants)")
    for cls, att in report["slo"]["per_class"].items():
        tbt = report["tbt"][cls]
        print(f"  {cls:>11}: attainment {att['attainment']:.3f} "
              f"({att['met']}/{att['resolved']}), observed TBT "
              f"p50/p99 {tbt['observed_p50_ms']:.1f}/"
              f"{tbt['observed_p99_ms']:.1f} ms")
    g = report["goodput"]
    print(f"  goodput: {g['slo_met_tokens_per_s']:.0f} SLO-met tok/s "
          f"of {g['tokens_per_s']:.0f} tok/s")

    f = report["fleet"]
    print("\n== what the kill cost ==")
    print(f"  device states: {report['devices']['states']}")
    print(f"  {f['device_deaths']} death detected, {f['migrations']} "
          f"migrations, {f['evictions']} evictions, "
          f"{f['replans']} replans, {f['event_loop_errors']} errors")
    print(f"  mean colocation gain {report['devices']['mean_gain']:.2f}x")

    # the determinism contract: same seed, same report, bit for bit
    twin = Simulator(generate_trace(cfg),
                     {f"dev{i}": TPU_V5E for i in range(6)}).run()
    print(f"\n  same seed -> identical report: {report == twin}")


if __name__ == "__main__":
    main()
