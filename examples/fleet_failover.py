"""Fleet failover walkthrough: admission -> eviction -> device kill ->
recovery, on a deterministic virtual clock.

Drives a 3-device `FleetScheduler` through a scripted fault trace with
`repro.ft.inject`: SLO decode workloads and a best-effort burst arrive,
one device stops heartbeating mid-run, the fleet drains it, re-places
every SLO workload on the survivors (evicting best-effort work, each
eviction an explicit `AdmissionDecision`), and — the recovery
invariant — ends in exactly the state a cold fleet over the survivors
would compute.

Run:  PYTHONPATH=src python examples/fleet_failover.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
from bench_planner import decode_heavy_mix  # noqa: E402

from repro.core import (BEST_EFFORT, SLO, TPU_V5E, FleetConfig,  # noqa: E402
                        FleetScheduler)
from repro.ft.inject import (FakeClock, FaultInjector, arrive,  # noqa: E402
                             kill, storm)


def main():
    works = decode_heavy_mix(TPU_V5E, n_decode=3, n_aux=4)
    decodes, auxes = works[:3], works[3:]

    clock = FakeClock()
    fleet = FleetScheduler(
        {f"dev{i}": TPU_V5E for i in range(3)},
        FleetConfig(max_group_size=3, heartbeat_timeout=3.0,
                    backoff_base=1.0, max_retries=3),
        clock=clock)

    trace = (
        # three latency-critical decode instances trickle in...
        [arrive(float(i), d, priority=SLO) for i, d in enumerate(decodes)]
        # ...then a best-effort burst lands on one tick
        + storm(3.0, auxes, priority=BEST_EFFORT)
        # ...and dev1's host dies (it simply stops heartbeating)
        + [kill(6.0, "dev1")]
    )
    FaultInjector(fleet, clock).run(trace, until=25.0)

    print("== decision log ==")
    for d in fleet.decisions:
        print(f"  {d}")

    plan = fleet.plan()
    print("\n== post-recovery fleet ==")
    print(f"  device states: {plan.device_states}")
    for did, p in sorted(plan.placements.items()):
        print(f"  {did}: {p}")
    if plan.queued or plan.degraded:
        print(f"  waiting: queued={plan.queued} degraded={plan.degraded}")
    slo_names = [d.name for d in decodes]
    print(f"  SLO re-placement rate: {plan.placement_rate(slo_names):.0%}")
    print(f"  evictions recorded: {fleet.stats['evicted']}, "
          f"migrations: {fleet.stats['migrated']}, "
          f"event-loop errors: {fleet.stats['errors']}")

    # the recovery invariant: online state == cold plan over survivors
    cold = FleetScheduler(
        {did: d.model for did, d in fleet.devices.items()
         if d.state != "dead"},
        fleet.cfg)
    for prof, prio in fleet.workloads:
        cold.submit(prof, priority=prio)
    same = fleet.plan().placed == cold.plan().placed
    print(f"  online plan == cold plan over survivors: {same}")


if __name__ == "__main__":
    main()
