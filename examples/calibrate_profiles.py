"""Profile-calibration walkthrough: recover what the hardware actually
does from colocated stressor measurements alone.

The setup mirrors the production problem `repro.calib` exists for. We
*believe* an analytic interference profile for each serving tenant
(derived from its registry model config, exactly like the trace
generator builds them). The hardware *actually* runs a perturbed
version of it — here, a hidden ground truth the synthetic backend
serves measurements from; on a real TPU, the same sweep would time
Pallas kernel colocations (``PallasBackend``). The pipeline:

  1. sweep — colocate each tenant's kernel with calibrated single-axis
     stressors (plus multi-stressor, reverse, and cache-polluter
     probes) and record observed slowdowns;
  2. fit — invert the water-filling estimator over those observations
     (batched coordinate descent; the estimator is the forward model);
  3. validate — score believed-vs-fitted predictions on held-out k-way
     mixes the fitter never saw.

The point of the printout: the STALE analytic profiles mispredict
colocation slowdowns by tens of percent, the FITTED ones land within a
few percent of the hidden truth — per-axis demands, working set, and
hit fraction included.

Run:  PYTHONPATH=src python examples/calibrate_profiles.py
"""
import numpy as np

from repro.calib import (SyntheticBackend, fit_profiles, holdout_mixes,
                         perturb_profile, profile_to_params, validate)
from repro.configs.registry import get_config
from repro.core import TPU_V5E
from repro.core.resources import RESOURCE_AXES
from repro.sim.traces import SLO, tenant_profile

DEV = TPU_V5E
MODELS = ("qwen3-1.7b", "falcon-mamba-7b", "phi3.5-moe-42b-a6.6b")


def believed_kernels(rng):
    """Analytic per-tenant kernels from registry model configs — the
    same construction the trace generator uses (family picks the
    resource-axis mix), one tenant per model family here."""
    out = {}
    for name in MODELS:
        arch = get_config(name)
        prof = tenant_profile(rng, arch.family, arch, DEV, SLO)
        out[arch.family] = prof.kernels[0]
    return out


def main():
    rng = np.random.default_rng(7)
    believed = believed_kernels(rng)
    # what the hardware ACTUALLY does: every nonzero axis demand (and
    # the duration) multiplicatively perturbed — compilers, batch
    # shapes, and cache behaviour drift profiles exactly like this
    truth = {n: perturb_profile(k, rng, scale=0.3, dev=DEV)
             for n, k in believed.items()}
    backend = SyntheticBackend(truth, DEV, seed=7)

    print("== 1. measure: the stressor x victim sweep ==")
    sweep = backend.run_sweep(sorted(truth))
    print(f"  {len(sweep)} colocated observations across "
          f"{len(sweep.victims)} victims on {DEV.name}")

    print("\n== 2. fit: invert the estimator over the observations ==")
    fitted = fit_profiles(sweep)
    for name in sorted(truth):
        b = profile_to_params(believed[name], DEV)
        t = profile_to_params(truth[name], DEV)
        f = profile_to_params(fitted[name], DEV)
        print(f"  {name}: axis utilization believed -> true (fitted)")
        for axis in RESOURCE_AXES:
            if max(b[f"u:{axis}"], t[f"u:{axis}"]) < 0.01:
                continue
            print(f"    {axis:>5}: {b[f'u:{axis}']:.3f} -> "
                  f"{t[f'u:{axis}']:.3f} (fitted {f[f'u:{axis}']:.3f})")

    print("\n== 3. validate on held-out k-way mixes ==")
    mixes = holdout_mixes(sorted(truth), np.random.default_rng(99))
    stale = validate(believed, backend, mixes)
    fresh = validate(fitted, backend, mixes)
    print(f"  stale analytic profiles: max rel error "
          f"{stale.max_rel_error:.1%} (mean {stale.mean_rel_error:.1%})")
    print(f"  fitted profiles:         max rel error "
          f"{fresh.max_rel_error:.1%} (mean {fresh.mean_rel_error:.1%})")
    print(f"  worst stale mix: {stale.worst_mix}")
    print("\nThe fleet wiring closes the loop online: "
          "FleetScheduler.attach_calibration(DriftMonitor()) watches "
          "predicted-vs-observed slowdown per tenant, flags sustained "
          "divergence, and refit_workload() re-fits + resubmits "
          "(see the drift gate in benchmarks/bench_calib.py).")


if __name__ == "__main__":
    main()
