"""Quickstart: the paper's methodology in 60 lines.

1. Build resource profiles for two workload phases (an MXU-bound prefill
   and an HBM-bound decode) on the TPU v5e resource model.
2. Quantify each phase's interference sensitivity (the paper's §4 sweep).
3. Run the ONLINE colocation scheduler: workloads arrive and leave, and
   `plan()` incrementally re-places them (k-way groups, SLO-guarded).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (TPU_V5E, ColocationScheduler, KernelProfile,
                        Scenario, WorkloadProfile, sensitivity_batch,
                        solve_scenarios)
from repro.core.resources import RESOURCE_AXES


def phase(name, **utils):
    demand = {r: 0.0 for r in RESOURCE_AXES}
    for axis, frac in utils.items():
        demand[axis] = frac * TPU_V5E.capacity(axis)
    return KernelProfile(name, demand=demand, duration=1.0)


def main():
    prefill = phase("prefill_32k", mxu=0.72, hbm=0.25, issue=0.30)
    decode = phase("decode", mxu=0.04, hbm=0.86, issue=0.12)
    train = phase("train_step", mxu=0.65, hbm=0.45, issue=0.35, ici=0.40)

    print("== sensitivity fingerprints (slowdown under a 90% stressor) ==")
    # all three fingerprints (3 phases x 7 axes x 5 lambdas) = one solve
    for p, rep in zip((prefill, decode, train),
                      sensitivity_batch((prefill, decode, train), TPU_V5E)):
        tops = ", ".join(f"{a}={rep.scores[a]:.2f}x" for a in rep.ranked()[:3])
        print(f"  {p.name:12s} dominant axis: {rep.dominant():6s} ({tops})")

    print("\n== pairwise colocation predictions (one Scenario batch) ==")
    pairs = ((prefill, decode), (prefill, train), (decode, train))
    br = solve_scenarios([Scenario((a, b)) for a, b in pairs], TPU_V5E)
    for s, (a, b) in enumerate(pairs):
        print(f"  {a.name:12s} + {b.name:12s} -> "
              f"{a.name}: {br.slowdowns[s, 0]:.2f}x, "
              f"{b.name}: {br.slowdowns[s, 1]:.2f}x")

    print("\n== online scheduler (SLO: 1.3x, up to 3-way groups) ==")
    sched = ColocationScheduler(TPU_V5E, max_group_size=3)
    for p in (prefill, decode, train):
        sched.submit(WorkloadProfile(p.name, (p,), slo_slowdown=1.3))
    plan = sched.plan()
    for pl in plan.placements:
        print("  colocate:", pl)
    print("  run solo:", plan.solo)

    sched.remove("train_step")          # departure: zero estimator work
    sched.submit(WorkloadProfile(       # arrival: prices only its row
        "decode_b", (phase("decode_b", mxu=0.03, hbm=0.30, issue=0.08),),
        slo_slowdown=1.3))
    plan = sched.plan()
    print("  after train_step leaves and decode_b arrives:")
    for pl in plan.placements:
        print("    colocate:", pl)
    print("    run solo:", plan.solo)
    print(f"  estimator scenarios solved so far: "
          f"{sched.stats['scenarios_solved']}")


if __name__ == "__main__":
    main()
