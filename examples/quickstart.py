"""Quickstart: the paper's methodology in 80 lines.

1. Build resource profiles for two workload phases (an MXU-bound prefill
   and an HBM-bound decode) on the TPU v5e resource model.
2. Quantify each phase's interference sensitivity (the paper's §4 sweep).
3. Run the ONLINE colocation scheduler: workloads arrive and leave, and
   `plan()` incrementally re-places them (k-way groups, SLO-guarded).
4. Rescue an SLO-violating decode fleet with the k-way slot-fraction
   search (paper §5.3 green contexts): partitioned groups of three share
   each device, at fractions the search finds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (TPU_V5E, ColocationScheduler, KernelProfile,
                        Scenario, WorkloadProfile, partition_curve,
                        sensitivity_batch, solve_scenarios)
from repro.core.resources import RESOURCE_AXES


def phase(name, **utils):
    demand = {r: 0.0 for r in RESOURCE_AXES}
    for axis, frac in utils.items():
        demand[axis] = frac * TPU_V5E.capacity(axis)
    return KernelProfile(name, demand=demand, duration=1.0)


def main():
    prefill = phase("prefill_32k", mxu=0.72, hbm=0.25, issue=0.30)
    decode = phase("decode", mxu=0.04, hbm=0.86, issue=0.12)
    train = phase("train_step", mxu=0.65, hbm=0.45, issue=0.35, ici=0.40)

    print("== sensitivity fingerprints (slowdown under a 90% stressor) ==")
    # all three fingerprints (3 phases x 7 axes x 5 lambdas) = one solve
    for p, rep in zip((prefill, decode, train),
                      sensitivity_batch((prefill, decode, train), TPU_V5E)):
        tops = ", ".join(f"{a}={rep.scores[a]:.2f}x" for a in rep.ranked()[:3])
        print(f"  {p.name:12s} dominant axis: {rep.dominant():6s} ({tops})")

    print("\n== pairwise colocation predictions (one Scenario batch) ==")
    pairs = ((prefill, decode), (prefill, train), (decode, train))
    br = solve_scenarios([Scenario((a, b)) for a, b in pairs], TPU_V5E)
    for s, (a, b) in enumerate(pairs):
        print(f"  {a.name:12s} + {b.name:12s} -> "
              f"{a.name}: {br.slowdowns[s, 0]:.2f}x, "
              f"{b.name}: {br.slowdowns[s, 1]:.2f}x")

    print("\n== online scheduler (SLO: 1.3x, up to 3-way groups) ==")
    sched = ColocationScheduler(TPU_V5E, max_group_size=3)
    for p in (prefill, decode, train):
        sched.submit(WorkloadProfile(p.name, (p,), slo_slowdown=1.3))
    plan = sched.plan()
    for pl in plan.placements:
        print("  colocate:", pl)
    print("  run solo:", plan.solo)

    sched.remove("train_step")          # departure: zero estimator work
    sched.submit(WorkloadProfile(       # arrival: prices only its row
        "decode_b", (phase("decode_b", mxu=0.03, hbm=0.30, issue=0.08),),
        slo_slowdown=1.3))
    plan = sched.plan()
    print("  after train_step leaves and decode_b arrives:")
    for pl in plan.placements:
        print("    colocate:", pl)
    print("    run solo:", plan.solo)
    print(f"  estimator scenarios solved so far: "
          f"{sched.stats['scenarios_solved']}")

    print("\n== k-way slot-fraction search (green contexts, §5.3) ==")
    # a decode fleet too bandwidth-hungry to share a device at full
    # rate, plus short best-effort compute bursts riding along
    def workload(name, slo, dur, **utils):
        d = {r: 0.0 for r in RESOURCE_AXES}
        for axis, frac in utils.items():
            d[axis] = frac * TPU_V5E.capacity(axis)
        return WorkloadProfile(name, (KernelProfile(
            name + "#step", demand=d, duration=dur),), slo_slowdown=slo)

    fleet = [workload(f"decode_{i}", 1.15, 1.0, mxu=0.4, vpu=0.1,
                      issue=0.1, smem=0.05, hbm=0.6, l2=0.6)
             for i in range(4)]
    fleet += [workload(f"distill_{i}", 12.0, 0.08, vpu=0.072, issue=0.004,
                       mxu=0.004, hbm=0.0016, l2=0.0016) for i in range(2)]
    sched = ColocationScheduler(TPU_V5E, max_group_size=3)
    for w in fleet:
        sched.submit(w)
    plan = sched.plan()
    for pl in plan.placements:
        fr = {n: round(f, 3) for n, f in pl.slot_fraction.items()}
        print(f"  colocate {' + '.join(pl.workloads)}  "
              f"slot fractions {fr or 'full sharing'}  "
              f"gain {pl.throughput_gain:.2f}")
    print("  run solo:", plan.solo or "nothing")

    # the §5.3 diagnostic: how each member's slowdown responds as one
    # member's slot share sweeps (the ray the legacy fixed grid explored)
    curves = partition_curve(fleet[:2] + fleet[4:5], TPU_V5E, member=2,
                             fractions=(0.125, 0.25, 0.5))
    print("  partition response (distill_0 share 12.5% -> 50%):")
    for name, slows in curves.items():
        print(f"    {name:10s}", " ".join(f"{s:6.2f}x" for s in slows))


if __name__ == "__main__":
    main()
