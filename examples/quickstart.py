"""Quickstart: the paper's methodology in 60 lines.

1. Build resource profiles for two workload phases (an MXU-bound prefill
   and an HBM-bound decode) on the TPU v5e resource model.
2. Quantify each phase's interference sensitivity (the paper's §4 sweep).
3. Ask the colocation planner whether they can share a slice within SLO.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (TPU_V5E, KernelProfile, WorkloadProfile,
                        estimate_batch, plan_colocation, sensitivity_batch)
from repro.core.resources import RESOURCE_AXES


def phase(name, **utils):
    demand = {r: 0.0 for r in RESOURCE_AXES}
    for axis, frac in utils.items():
        demand[axis] = frac * TPU_V5E.capacity(axis)
    return KernelProfile(name, demand=demand, duration=1.0)


def main():
    prefill = phase("prefill_32k", mxu=0.72, hbm=0.25, issue=0.30)
    decode = phase("decode", mxu=0.04, hbm=0.86, issue=0.12)
    train = phase("train_step", mxu=0.65, hbm=0.45, issue=0.35, ici=0.40)

    print("== sensitivity fingerprints (slowdown under a 90% stressor) ==")
    # all three fingerprints (3 phases x 7 axes x 5 lambdas) = one solve
    for p, rep in zip((prefill, decode, train),
                      sensitivity_batch((prefill, decode, train), TPU_V5E)):
        tops = ", ".join(f"{a}={rep.scores[a]:.2f}x" for a in rep.ranked()[:3])
        print(f"  {p.name:12s} dominant axis: {rep.dominant():6s} ({tops})")

    print("\n== pairwise colocation predictions (one batched solve) ==")
    pairs = ((prefill, decode), (prefill, train), (decode, train))
    for (a, b), r in zip(pairs, estimate_batch(pairs, TPU_V5E)):
        print(f"  {a.name:12s} + {b.name:12s} -> "
              + ", ".join(f"{k}: {v:.2f}x" for k, v in r.slowdowns.items()))

    print("\n== planner (SLO: 1.3x) ==")
    works = [WorkloadProfile(p.name, (p,), slo_slowdown=1.3)
             for p in (prefill, decode, train)]
    plan = plan_colocation(works, TPU_V5E)
    for pl in plan.placements:
        print("  colocate:", pl)
    print("  run solo:", plan.solo)


if __name__ == "__main__":
    main()
