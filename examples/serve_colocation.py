"""Serving with interference-aware chunked prefill (paper §4.2/§5.1).

Runs the same request mix through the engine in `serial` mode (monolithic
prefills -> head-of-line blocking of the decode batch) and in
`interference_aware` mode (prefill chunks sized per-step by pricing
decode-vs-chunk `Scenario`s so the decode batch's TBT stays within SLO),
and compares decode-gap statistics.

Run:  PYTHONPATH=src python examples/serve_colocation.py
"""
import numpy as np

from repro.configs.registry import get_config, tiny_config
from repro.core import Scenario, solve_scenarios
from repro.serve import Engine, EngineConfig


def run(mode: str):
    cfg = tiny_config(get_config("qwen3-1.7b"))
    eng = Engine(cfg, ecfg=EngineConfig(max_slots=4, max_len=768,
                                        prefill_chunk=64, mode=mode,
                                        tbt_slo_ms=1e-6))
    # a decode-heavy workload...
    for _ in range(3):
        eng.submit(list(np.random.default_rng(0).integers(1, 99, 12)),
                   max_new=30)
    for _ in range(5):
        eng.step()
    # ...interrupted by a LONG prompt (the paper's sleep-kernel analogue)
    eng.submit(list(np.random.default_rng(1).integers(1, 99, 512)), max_new=4)
    eng.run_until_done()

    # structural HOL metric: how many decode steps ran BETWEEN the long
    # prompt's first and last prefill chunk (serial: 0 — the decode batch
    # stalls for the whole monolithic prefill). Wall-clock on this CPU
    # container is dominated by XLA compiles, so the schedule itself is
    # the meaningful observable.
    kinds = [e.kind for e in eng.events]
    big_chunks = [i for i, e in enumerate(eng.events)
                  if e.kind == "prefill_chunk" and e.detail.get("chunk", 0) >= 16
                  and i > 8]
    interleaved = (kinds[big_chunks[0]:big_chunks[-1]].count("decode")
                   if len(big_chunks) > 1 else 0)
    chunks = [e.detail["chunk"] for e in eng.events
              if e.kind == "prefill_chunk"]
    print(f"mode={mode:20s} long prompt split into "
          f"{len(chunks) - 3} chunk(s); decode steps interleaved during "
          f"its prefill: {interleaved}")
    return interleaved


def show_chunk_pricing():
    """The engine's per-step decision, spelled out: one Scenario per
    chunk candidate (victim = decode batch, background = the chunk)."""
    cfg = tiny_config(get_config("qwen3-1.7b"))
    eng = Engine(cfg, ecfg=EngineConfig())
    decode = eng._phase_profile("decode", 3)
    cands = [256, 128, 64, 32]
    chunks = [eng._phase_profile(f"prefill{c}", c) for c in cands]
    br = solve_scenarios([Scenario((decode,), (ch,)) for ch in chunks],
                         eng.dev)
    print("\nchunk-size pricing (decode batch of 3):")
    for c, s in zip(cands, br.slowdowns[:, 0]):
        print(f"  chunk {c:4d} -> predicted decode slowdown {s:.2f}x")


def main():
    i_serial = run("serial")
    i_aware = run("interference_aware")
    print(f"\nHOL mitigation: serial interleaves {i_serial} decode steps "
          f"during the long prefill; interference-aware interleaves "
          f"{i_aware} (decode batch keeps flowing)")
    assert i_aware > i_serial
    show_chunk_pricing()


if __name__ == "__main__":
    main()
