"""End-to-end training driver: a ~100M-parameter qwen3-family LM trained
for a few hundred steps on the synthetic pipeline, with checkpointing,
auto-resume and straggler monitoring — the full production loop at
CPU-runnable scale.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""
import argparse

import jax

from repro.configs.registry import get_config
from repro.configs.base import RunConfig
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import build_model
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    # ~100M params: a scaled-down qwen3 (same family: qk-norm, GQA)
    cfg = get_config("qwen3-1.7b").with_overrides(
        n_layers=6, d_model=512, d_ff=1536, vocab_size=8192,
        attn=get_config("qwen3-1.7b").attn.__class__(
            n_heads=8, n_kv_heads=4, head_dim=64, qk_norm=True),
        attn_impl="flashref")
    model = build_model(cfg)
    print(f"params: {cfg.n_params() / 1e6:.1f}M")

    tcfg = TrainerConfig(total_steps=args.steps, log_every=20,
                         checkpoint_dir=args.ckpt, checkpoint_every=100,
                         optimizer="adamw", lr=3e-4)
    trainer = Trainer(model, RunConfig(num_microbatches=2), tcfg)
    data = Prefetcher(SyntheticLM(cfg, DataConfig(
        seq_len=256, global_batch=8, vocab_size=cfg.vocab_size)))
    params, _, history = trainer.fit(data, jax.random.PRNGKey(0))
    data.close()
    first, last = history[0][1], history[-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first - 0.3 else 'check convergence'})")
    if trainer.straggler.events:
        print(f"straggler events: {trainer.straggler.events}")


if __name__ == "__main__":
    main()
