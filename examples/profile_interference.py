"""Profile this framework's own workloads with the paper's methodology.

Reads the dry-run artifacts (run repro.launch.dryrun first), builds
per-phase resource vectors, prints sensitivity fingerprints, and plans
cross-architecture colocations on a shared v5e slice.

Run:  PYTHONPATH=src python examples/profile_interference.py
"""
from repro.launch.profile import main

if __name__ == "__main__":
    main(["--plan"])
