"""Trace-driven serving gate: SLO attainment under sustained load.

Replays a fixed seeded diurnal+burst trace (>=1k requests, >=32 tenants,
a mid-trace device kill) through the `repro.sim` closed loop — fleet
event loop + interference-inflated request serving on one virtual
clock — and gates the paper's operational claim: the
estimator/scheduler/fleet stack keeps *per-request* SLOs predictable
under multi-tenant colocation, arrival storms, churn, and faults.

Gates (the CI contract):
  1. SLO-class attainment >= 0.95 on the fixed trace, with the kill's
     outage as the only tolerated misses;
  2. zero event-loop errors (the fleet's no-crash contract holds under
     ~3k scripted events);
  3. determinism — the whole generate->simulate->report pipeline is run
     TWICE from the same seed and the reports must match bit-for-bit;
  4. trace floor — the gate is meaningless on a toy tape, so the trace
     itself must carry >=1000 requests, >=32 tenants, >=1 device death.

`--quick` (the CI smoke) runs the same fixed trace — it is already
sized to the floor — and writes BENCH_trace.json as a CI artifact next
to the planner/fleet benches.  The full run adds a calm (fault-free)
and a storm-heavy variant for context; only the fixed trace gates.

  PYTHONPATH=src python benchmarks/bench_trace.py          # full
  PYTHONPATH=src python benchmarks/bench_trace.py --quick  # CI gate
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import TPU_V5E, TPU_V5P
from repro.sim import Simulator, TraceConfig, generate_trace

# the fixed gate trace: 36 tenants (half SLO) on 12 devices (36 slots at
# k=3), 240 virtual seconds of diurnal+burst traffic (~2.5k requests),
# dev3 killed mid-trace while a burst window is possible.  The fleet is
# HETEROGENEOUS — alternating v5e/v5p — so every pricing decision, the
# kill recovery, and the determinism twin exercise two device models.
GATE_TRACE = TraceConfig(seed=2026, duration=240.0, n_tenants=36,
                         kills=((120.0, "dev3"),))
GATE_DEVICES = 12
ATTAINMENT_TARGET = 0.95


def hetero_models(n_devices: int) -> dict:
    """Alternating two-model mix: even devices v5e, odd devices v5p."""
    return {f"dev{i}": (TPU_V5E if i % 2 == 0 else TPU_V5P)
            for i in range(n_devices)}


def run_once(cfg: TraceConfig, n_devices: int = GATE_DEVICES) -> dict:
    """One full generate -> simulate -> report pass (fresh RNG, fresh
    clock, fresh fleet — everything derives from cfg.seed)."""
    trace = generate_trace(cfg)
    sim = Simulator(trace, hetero_models(n_devices))
    return sim.run()


def gate(report: dict, twin: dict) -> dict:
    """Evaluate the acceptance gates against the fixed-trace report and
    its same-seed twin."""
    slo_cls = report["slo"]["per_class"].get("slo", {"attainment": 0.0})
    checks = {
        "slo_attainment": slo_cls["attainment"] >= ATTAINMENT_TARGET,
        "no_event_loop_errors": report["fleet"]["event_loop_errors"] == 0,
        "deterministic": report == twin,
        "trace_floor": (report["requests"]["total"] >= 1000
                        and report["trace"]["tenants"] >= 32
                        and report["fleet"]["device_deaths"] >= 1),
        # two genuinely different device models in the gate fleet
        "heterogeneous_fleet": len({m.name for m in
                                    hetero_models(GATE_DEVICES).values()
                                    }) == 2,
    }
    checks["all"] = all(checks.values())
    return checks


def describe(tag: str, report: dict) -> None:
    req, slo, tbt = report["requests"], report["slo"], report["tbt"]
    fleet, good = report["fleet"], report["goodput"]
    print(f"== {tag} ==")
    print(f"  trace: {report['trace']['tenants']} tenants "
          f"({report['trace']['slo_tenants']} SLO-class), "
          f"{req['total']} requests "
          f"({req['completed']} completed, {req['canceled']} canceled, "
          f"{req['unfinished']} unfinished)")
    for cls in sorted(slo["per_class"]):
        a = slo["per_class"][cls]
        t = tbt[cls]
        print(f"  {cls:>11}: attainment {a['attainment']:.3f} "
              f"({a['met']}/{a['resolved']} resolved), "
              f"TBT p50/p99 {t['observed_p50_ms']:.1f}/"
              f"{t['observed_p99_ms']:.1f} ms observed, "
              f"{t['service_p50_ms']:.1f}/{t['service_p99_ms']:.1f} ms "
              f"service")
    print(f"  goodput: {good['slo_met_tokens_per_s']:.0f} SLO-met tok/s "
          f"of {good['tokens_per_s']:.0f} tok/s "
          f"({good['requests_per_s']:.2f} req/s)")
    util = report["devices"]["utilization"]
    print(f"  fleet: {fleet['replans']} replans, "
          f"{fleet['migrations']} migrations, "
          f"{fleet['evictions']} evictions, "
          f"{fleet['device_deaths']} device deaths, "
          f"{fleet['event_loop_errors']} errors; "
          f"mean gain {report['devices']['mean_gain']:.2f}x, "
          f"mean util {sum(util.values()) / max(len(util), 1):.2f}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI gate: fixed trace only; writes "
                         "BENCH_trace.json unless --json overrides it")
    ap.add_argument("--json", type=str, default=None,
                    help="write a machine-readable result summary to this "
                         "path (implied as BENCH_trace.json by --quick)")
    args = ap.parse_args(argv)

    report = run_once(GATE_TRACE)
    twin = run_once(GATE_TRACE)      # same seed, fresh everything
    describe("gate trace (diurnal + bursts + kill)", report)
    checks = gate(report, twin)

    variants = {}
    if not args.quick:
        calm = run_once(TraceConfig(seed=7, duration=240.0, n_tenants=36))
        stormy = run_once(TraceConfig(seed=11, duration=240.0, n_tenants=36,
                                      burst_factor=6.0, n_bursts=5,
                                      kills=((100.0, "dev1"),
                                             (160.0, "dev7"))))
        describe("calm variant (no faults)", calm)
        describe("stormy variant (2 kills, 6x bursts)", stormy)
        variants = {"calm": calm, "stormy": stormy}

    print("\n== acceptance ==")
    slo_att = report["slo"]["per_class"].get("slo", {}).get("attainment", 0.0)
    print(f"  SLO-class attainment {slo_att:.3f} >= {ATTAINMENT_TARGET}: "
          f"{'PASS' if checks['slo_attainment'] else 'FAIL'}")
    print(f"  0 event-loop errors: "
          f"{'PASS' if checks['no_event_loop_errors'] else 'FAIL'}")
    print(f"  same seed -> identical report: "
          f"{'PASS' if checks['deterministic'] else 'FAIL'}")
    print(f"  trace floor (>=1k requests, >=32 tenants, >=1 kill): "
          f"{'PASS' if checks['trace_floor'] else 'FAIL'}")

    json_path = args.json or ("BENCH_trace.json" if args.quick else None)
    if json_path:
        payload = {"gate": report, "acceptance": checks, **variants}
        Path(json_path).write_text(json.dumps(payload, indent=2,
                                              sort_keys=True) + "\n")
        print(f"\n  wrote {json_path}")
    return 0 if checks["all"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
