"""Estimator + planner throughput: batched/incremental vs the seed.

Measures
  1. estimator solves/sec: seed pure-Python `estimate`, the current scalar
     wrapper looped, and `estimate_batch` in one vectorized pass over the
     same scenarios (target: batch >= 10x looped on 1k scenarios);
  2. `plan_colocation` wall-time at n in {16, 64, 256, 1024} workloads
     (target: >= 20x vs the seed O(n^3) planner at n=256).

Outputs are cross-checked against the seed at <= 1e-9 (slowdowns,
speeds, plus placement-for-placement Plan equality) wherever the seed is
actually run; beyond --seed-cap workloads the seed planner would take
hours, so its time is extrapolated from its measured per-pair cost and
marked "est".

  PYTHONPATH=src python benchmarks/bench_planner.py          # full sweep
  PYTHONPATH=src python benchmarks/bench_planner.py --quick  # CI smoke
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

import _seed_reference as seed
from repro.core import (TPU_V5E, KernelProfile, WorkloadProfile, estimate,
                        estimate_batch, plan_colocation)
from repro.core.resources import RESOURCE_AXES

TOL = 1e-9


# ------------------------------------------------------------------ #
#  Random workload generation (continuous draws: no branch ties).     #
#  tests/test_batch_estimator.py imports these so the oracle tests    #
#  and the benchmark fuzz the same input distribution; the optional   #
#  flags steer draws into specific estimator branches and leave the   #
#  default draw sequence untouched.                                   #
# ------------------------------------------------------------------ #
def random_profile(rng, name, dev, zero_axes=False, smem_heavy=False,
                   cache_heavy=False):
    d = {r: float(rng.uniform(0.02, 1.1)) * dev.capacity(r)
         for r in RESOURCE_AXES}
    if zero_axes and rng.random() < 0.3:
        for r in rng.choice(RESOURCE_AXES, size=3, replace=False):
            d[r] = 0.0
    if smem_heavy:
        d["smem"] = float(rng.uniform(0.8, 1.6)) * dev.capacity("smem")
    ws, hit = 0.0, 0.0
    if cache_heavy or rng.random() < 0.3:
        ws = float(rng.uniform(0.1, 1.5)) * dev.cache_capacity
        hit = float(rng.uniform(0.1, 1.0))
    return KernelProfile(
        name, demand=d,
        duration=float(rng.uniform(0.5, 2.0)) if rng.random() < 0.5 else None,
        cache_working_set=ws, cache_hit_fraction=hit)


def random_scenarios(rng, n, dev):
    return [[random_profile(rng, f"s{s}k{i}", dev)
             for i in range(int(rng.integers(2, 5)))] for s in range(n)]


def random_workloads(rng, n, dev):
    return [WorkloadProfile(
        f"w{i}",
        tuple(random_profile(rng, f"w{i}p{j}", dev)
              for j in range(int(rng.integers(1, 3)))),
        slo_slowdown=float(rng.uniform(1.1, 1.6)))
        for i in range(n)]


# ------------------------------------------------------------------ #
#  Checks                                                             #
# ------------------------------------------------------------------ #
def max_result_diff(a, b) -> float:
    return max(
        max(abs(a.slowdowns[k] - b.slowdowns[k]) for k in b.slowdowns),
        max(abs(a.speeds[k] - b.speeds[k]) for k in b.speeds))


def assert_plans_equal(got, want):
    assert [p.workloads for p in got.placements] == \
        [p.workloads for p in want.placements], "placement order differs"
    assert got.solo == want.solo, "solo set differs"
    for g, w in zip(got.placements, want.placements):
        assert g.slot_fraction == w.slot_fraction
        assert g.meets_slo == w.meets_slo
        assert abs(g.throughput_gain - w.throughput_gain) <= TOL
        for k in w.predicted_slowdown:
            assert abs(g.predicted_slowdown[k]
                       - w.predicted_slowdown[k]) <= TOL


# ------------------------------------------------------------------ #
#  Benches                                                            #
# ------------------------------------------------------------------ #
def _best_of(fn, reps: int = 3):
    """Min wall-time over reps (standard noise suppression) + last result."""
    best, result = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def bench_estimator(n_scenarios: int, dev) -> float:
    rng = np.random.default_rng(0)
    scenarios = random_scenarios(rng, n_scenarios, dev)

    t_seed, seed_results = _best_of(
        lambda: [seed.estimate(sc, dev) for sc in scenarios])
    t_loop, loop_results = _best_of(
        lambda: [estimate(sc, dev) for sc in scenarios])
    t_batch, batch_results = _best_of(
        lambda: estimate_batch(scenarios, dev))

    err_loop = max(max_result_diff(g, w)
                   for g, w in zip(batch_results, loop_results))
    err_seed = max(max_result_diff(g, w)
                   for g, w in zip(batch_results, seed_results))
    assert err_loop <= TOL, f"batch vs looped estimate: {err_loop:.2e}"
    assert err_seed <= TOL, f"batch vs seed estimate: {err_seed:.2e}"

    print(f"\n== estimator: {n_scenarios} scenarios (2-4 kernels each) on "
          f"{dev.name} ==")
    print(f"  seed scalar loop   {t_seed:8.3f}s  "
          f"({n_scenarios / t_seed:9.0f} solves/s)")
    print(f"  wrapper loop       {t_loop:8.3f}s  "
          f"({n_scenarios / t_loop:9.0f} solves/s)")
    print(f"  estimate_batch     {t_batch:8.3f}s  "
          f"({n_scenarios / t_batch:9.0f} solves/s)")
    print(f"  batch vs looped    {t_loop / t_batch:8.1f}x   "
          f"(max |diff| {max(err_loop, err_seed):.1e})")
    print(f"  batch vs seed      {t_seed / t_batch:8.1f}x")
    return t_loop / t_batch


def bench_planner(ns, seed_cap: int, dev) -> dict:
    print(f"\n== planner: greedy SLO-feasible pairing on {dev.name} ==")
    print(f"  {'n':>5} {'pairs':>8} {'new (s)':>9} {'seed (s)':>10} "
          f"{'speedup':>9}  plan")
    speedups = {}
    per_pair_cost = None
    for n in ns:
        rng = np.random.default_rng(42)
        works = random_workloads(rng, n, dev)
        pairs = n * (n - 1) // 2

        t0 = time.perf_counter()
        plan = plan_colocation(works, dev)
        t_new = time.perf_counter() - t0
        rounds = len(plan.placements) + 1

        if n <= seed_cap:
            t0 = time.perf_counter()
            seed_plan = seed.plan_colocation(works, dev)
            t_seed = time.perf_counter() - t0
            assert_plans_equal(plan, seed_plan)
            # greedy rounds each rescan ~all pairs: amortized per-pair cost
            per_pair_cost = t_seed / (rounds * pairs)
            tag = ""
        elif per_pair_cost is not None:
            t_seed = per_pair_cost * rounds * pairs
            tag = " est"
        else:
            t_seed, tag = float("nan"), " n/a"
        speedups[n] = t_seed / t_new
        print(f"  {n:>5} {pairs:>8} {t_new:>9.3f} {t_seed:>10.2f}{tag:<4}"
              f"{t_seed / t_new:>8.0f}x  "
              f"{len(plan.placements)} pairs, {len(plan.solo)} solo, "
              f"gain {plan.total_gain:.2f}")
    return speedups


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small n, fewer scenarios")
    ap.add_argument("--n", type=int, nargs="*", default=None,
                    help="workload counts to plan (default 16 64 256 1024)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="estimator batch size (default 1000)")
    ap.add_argument("--seed-cap", type=int, default=None,
                    help="largest n at which the seed planner actually runs "
                         "(beyond: extrapolated; default 256, quick 64)")
    args = ap.parse_args(argv)

    if args.quick:
        ns = args.n or [16, 64]
        n_scen = args.scenarios or 250
        seed_cap = args.seed_cap if args.seed_cap is not None else 64
    else:
        ns = args.n or [16, 64, 256, 1024]
        n_scen = args.scenarios or 1000
        seed_cap = args.seed_cap if args.seed_cap is not None else 256

    batch_speedup = bench_estimator(n_scen, TPU_V5E)
    plan_speedups = bench_planner(ns, seed_cap, TPU_V5E)

    print("\n== acceptance ==")
    ok_batch = batch_speedup >= 10
    print(f"  estimate_batch >= 10x looped estimate: "
          f"{'PASS' if ok_batch else 'FAIL'} ({batch_speedup:.1f}x)")
    target_n = 256
    if target_n in plan_speedups:
        ok_plan = plan_speedups[target_n] >= 20
        print(f"  plan_colocation >= 20x seed @ n={target_n}: "
              f"{'PASS' if ok_plan else 'FAIL'} "
              f"({plan_speedups[target_n]:.0f}x)")
    else:
        ok_plan = all(s >= 20 for k, s in plan_speedups.items()
                      if k >= 64 and np.isfinite(s))
        print(f"  plan_colocation >= 20x seed (n<=cap measured): "
              f"{'PASS' if ok_plan else 'FAIL'} "
              f"({ {k: round(v, 1) for k, v in plan_speedups.items()} })")
    return 0 if (ok_batch and ok_plan) else 1


if __name__ == "__main__":
    sys.exit(main())
