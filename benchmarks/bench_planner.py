"""Estimator + scheduler throughput: batched/incremental vs the seed.

Measures
  1. estimator solves/sec: seed pure-Python `estimate`, the current scalar
     wrapper looped, and `estimate_batch` in one vectorized pass over the
     same scenarios (target: batch >= 10x looped on 1k scenarios);
  2. cold `ColocationScheduler.plan()` wall-time at n in {16, 64, 256,
     1024} workloads (target: >= 20x vs the seed O(n^3) planner at n=256);
  3. online churn: with n resident workloads, arrive/leave events must
     replan with O(n) estimator scenarios each (the cached price matrix
     makes re-planning a row update, not an O(n^2) re-price);
  4. the partition-search gate: on the SLO-tight decode-heavy mix the
     k-way slot-fraction search must strictly beat the legacy fixed-grid
     pair planner in total gain via partitioned groups of size > 2;
  5. the jax solver-backend gate: numpy/jax parity at 1e-9 on a 10k
     mixed-width scenario sweep, a batch-size throughput sweep (jax must
     reach >= 10x the deployed numpy estimate_batch baseline at batch
     >= 4096), and the denser jax-default fraction search matching the
     partition gate's gain.

`--quick` (the CI smoke) also writes BENCH_planner.json — plan latency,
scenarios/arrival, and the partition-search gate in machine-readable
form, uploaded as a CI artifact.

Outputs are cross-checked against the seed at <= 1e-9 (slowdowns,
speeds, plus placement-for-placement Plan equality) wherever the seed is
actually run; beyond --seed-cap workloads the seed planner would take
hours, so its time is extrapolated from its measured per-pair cost and
marked "est".

  PYTHONPATH=src python benchmarks/bench_planner.py          # full sweep
  PYTHONPATH=src python benchmarks/bench_planner.py --quick  # CI smoke
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

import _seed_reference as seed
from repro.core import (LEGACY_SEARCH, TPU_V5E, ColocationScheduler,
                        KernelProfile, WorkloadProfile, estimate,
                        estimate_batch)
from repro.core.resources import RESOURCE_AXES

TOL = 1e-9


def cold_plan(works, dev, max_group_size=2, search=None):
    """One-shot plan through the online API (what `plan_colocation`
    forwards to, minus the DeprecationWarning).  `search=LEGACY_SEARCH`
    reproduces the seed's fixed-grid pair behavior bit-for-bit; the
    default is the full k-way fraction search."""
    sched = ColocationScheduler(dev, max_group_size=max_group_size,
                                fraction_search=search)
    for w in works:
        sched.submit(w)
    return sched.plan()


# ------------------------------------------------------------------ #
#  Random workload generation (continuous draws: no branch ties).     #
#  tests/test_batch_estimator.py imports these so the oracle tests    #
#  and the benchmark fuzz the same input distribution; the optional   #
#  flags steer draws into specific estimator branches and leave the   #
#  default draw sequence untouched.                                   #
# ------------------------------------------------------------------ #
def random_profile(rng, name, dev, zero_axes=False, smem_heavy=False,
                   cache_heavy=False):
    d = {r: float(rng.uniform(0.02, 1.1)) * dev.capacity(r)
         for r in RESOURCE_AXES}
    if zero_axes and rng.random() < 0.3:
        for r in rng.choice(RESOURCE_AXES, size=3, replace=False):
            d[r] = 0.0
    if smem_heavy:
        d["smem"] = float(rng.uniform(0.8, 1.6)) * dev.capacity("smem")
    ws, hit = 0.0, 0.0
    if cache_heavy or rng.random() < 0.3:
        ws = float(rng.uniform(0.1, 1.5)) * dev.cache_capacity
        hit = float(rng.uniform(0.1, 1.0))
    return KernelProfile(
        name, demand=d,
        duration=float(rng.uniform(0.5, 2.0)) if rng.random() < 0.5 else None,
        cache_working_set=ws, cache_hit_fraction=hit)


def random_scenarios(rng, n, dev):
    return [[random_profile(rng, f"s{s}k{i}", dev)
             for i in range(int(rng.integers(2, 5)))] for s in range(n)]


def random_workloads(rng, n, dev):
    return [WorkloadProfile(
        f"w{i}",
        tuple(random_profile(rng, f"w{i}p{j}", dev)
              for j in range(int(rng.integers(1, 3)))),
        slo_slowdown=float(rng.uniform(1.1, 1.6)))
        for i in range(n)]


def decode_heavy_mix(dev, n_decode=4, n_aux=2):
    """The SLO-tight decode-heavy mix of the partition-search gate
    (tests/test_fracsearch.py imports it — single source of truth).

    Decode instances are bandwidth-bound (hbm/l2 0.6) with light compute
    and a tight 1.15x SLO: two of them over-commit the device-wide
    bandwidth axes at full share, but slot-partitioning (0.5, 0.5)
    throttles each other's representative to its slice and rescues the
    pair.  The aux jobs are short best-effort VPU bursts (distillation /
    eval-style) whose partitioned representative freezes on an axis the
    decodes never contend on, so a k-way fraction search can pack
    decode+decode+aux per device — the fixed-grid pair planner cannot."""
    def prof(name, slo, dur, **u):
        d = {r: u.get(r, 0.0) * dev.capacity(r) for r in RESOURCE_AXES}
        return WorkloadProfile(
            name, (KernelProfile(f"{name}#step", demand=d, duration=dur),),
            slo_slowdown=slo)

    decodes = [prof(f"decode{i}", 1.15, 1.0, mxu=0.4, vpu=0.1, issue=0.1,
                    smem=0.05, hbm=0.6, l2=0.6) for i in range(n_decode)]
    aux = [prof(f"aux{i}", 12.0, 0.08, vpu=0.072, issue=0.004, mxu=0.004,
                hbm=0.0016, l2=0.0016) for i in range(n_aux)]
    return decodes + aux


# ------------------------------------------------------------------ #
#  Checks                                                             #
# ------------------------------------------------------------------ #
def max_result_diff(a, b) -> float:
    return max(
        max(abs(a.slowdowns[k] - b.slowdowns[k]) for k in b.slowdowns),
        max(abs(a.speeds[k] - b.speeds[k]) for k in b.speeds))


def assert_plans_equal(got, want):
    assert [p.workloads for p in got.placements] == \
        [p.workloads for p in want.placements], "placement order differs"
    assert got.solo == want.solo, "solo set differs"
    for g, w in zip(got.placements, want.placements):
        assert g.slot_fraction == w.slot_fraction
        assert g.meets_slo == w.meets_slo
        assert abs(g.throughput_gain - w.throughput_gain) <= TOL
        for k in w.predicted_slowdown:
            assert abs(g.predicted_slowdown[k]
                       - w.predicted_slowdown[k]) <= TOL


# ------------------------------------------------------------------ #
#  Benches                                                            #
# ------------------------------------------------------------------ #
def _best_of(fn, reps: int = 3):
    """Min wall-time over reps (standard noise suppression) + last result."""
    best, result = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def bench_estimator(n_scenarios: int, dev) -> float:
    rng = np.random.default_rng(0)
    scenarios = random_scenarios(rng, n_scenarios, dev)

    t_seed, seed_results = _best_of(
        lambda: [seed.estimate(sc, dev) for sc in scenarios])
    t_loop, loop_results = _best_of(
        lambda: [estimate(sc, dev) for sc in scenarios])
    t_batch, batch_results = _best_of(
        lambda: estimate_batch(scenarios, dev))

    err_loop = max(max_result_diff(g, w)
                   for g, w in zip(batch_results, loop_results))
    err_seed = max(max_result_diff(g, w)
                   for g, w in zip(batch_results, seed_results))
    assert err_loop <= TOL, f"batch vs looped estimate: {err_loop:.2e}"
    assert err_seed <= TOL, f"batch vs seed estimate: {err_seed:.2e}"

    print(f"\n== estimator: {n_scenarios} scenarios (2-4 kernels each) on "
          f"{dev.name} ==")
    print(f"  seed scalar loop   {t_seed:8.3f}s  "
          f"({n_scenarios / t_seed:9.0f} solves/s)")
    print(f"  wrapper loop       {t_loop:8.3f}s  "
          f"({n_scenarios / t_loop:9.0f} solves/s)")
    print(f"  estimate_batch     {t_batch:8.3f}s  "
          f"({n_scenarios / t_batch:9.0f} solves/s)")
    print(f"  batch vs looped    {t_loop / t_batch:8.1f}x   "
          f"(max |diff| {max(err_loop, err_seed):.1e})")
    print(f"  batch vs seed      {t_seed / t_batch:8.1f}x")
    return t_loop / t_batch


def bench_planner(ns, seed_cap: int, dev) -> dict:
    print(f"\n== planner: greedy SLO-feasible pairing on {dev.name} ==")
    print(f"  {'n':>5} {'pairs':>8} {'new (s)':>9} {'seed (s)':>10} "
          f"{'speedup':>9}  plan")
    speedups = {}
    latency = {}
    per_pair_cost = None
    for n in ns:
        rng = np.random.default_rng(42)
        works = random_workloads(rng, n, dev)
        pairs = n * (n - 1) // 2

        # headline timing: the DEFAULT config (full fraction search)
        t0 = time.perf_counter()
        plan = cold_plan(works, dev)
        t_new = time.perf_counter() - t0
        latency[n] = t_new
        rounds = len(plan.placements) + 1

        if n <= seed_cap:
            t0 = time.perf_counter()
            seed_plan = seed.plan_colocation(works, dev)
            t_seed = time.perf_counter() - t0
            # equivalence oracle: the LEGACY fixed-grid config must
            # reproduce the seed planner placement-for-placement
            assert_plans_equal(cold_plan(works, dev, search=LEGACY_SEARCH),
                               seed_plan)
            # greedy rounds each rescan ~all pairs: amortized per-pair cost
            per_pair_cost = t_seed / (rounds * pairs)
            tag = ""
        elif per_pair_cost is not None:
            t_seed = per_pair_cost * rounds * pairs
            tag = " est"
        else:
            t_seed, tag = float("nan"), " n/a"
        speedups[n] = t_seed / t_new
        print(f"  {n:>5} {pairs:>8} {t_new:>9.3f} {t_seed:>10.2f}{tag:<4}"
              f"{t_seed / t_new:>8.0f}x  "
              f"{len(plan.placements)} pairs, {len(plan.solo)} solo, "
              f"gain {plan.total_gain:.2f}")
    return {"speedups": speedups, "latency_s": latency}


def bench_churn(n: int, events: int, dev, max_group_size: int = 2) -> dict:
    """Online arrive/leave trace: per-event estimator work must stay O(n).

    Starts from a cold pool of n workloads, then alternates departures
    (random resident) and arrivals (fresh workload), replanning after
    every event. Reports wall-time and estimator-scenario counts per
    event, cross-checked for placement equality against a cold plan on
    the surviving set after the last event."""
    rng = np.random.default_rng(7)
    pool = random_workloads(rng, n + (events + 1) // 2, dev)
    sched = ColocationScheduler(dev, max_group_size=max_group_size)
    for w in pool[:n]:
        sched.submit(w)
    t0 = time.perf_counter()
    sched.plan()
    t_cold = time.perf_counter() - t0
    cold_scen = sched.stats["scenarios_solved"]

    resident = list(pool[:n])
    fresh = list(pool[n:])
    arr_t, dep_t, arr_scen, dep_scen = [], [], [], []
    for e in range(events):
        s0 = sched.stats["scenarios_solved"]
        t0 = time.perf_counter()
        if e % 2 == 0:                      # departure
            p0 = sched.stats["pairs_priced"]
            victim = resident.pop(int(rng.integers(len(resident))))
            sched.remove(victim.name)
            sched.plan()
            dep_t.append(time.perf_counter() - t0)
            assert sched.stats["pairs_priced"] == p0, \
                "departure must not re-price any pair"
            if max_group_size == 2:
                # k>2 replans may legitimately price never-seen GROUP
                # combinations; the pairwise matrix is always untouched
                assert sched.stats["scenarios_solved"] == s0, \
                    "departure must not trigger estimator work at k=2"
            dep_scen.append(sched.stats["scenarios_solved"] - s0)
        else:                               # arrival
            w = fresh.pop()
            resident.append(w)
            sched.submit(w)
            sched.plan()
            arr_t.append(time.perf_counter() - t0)
            arr_scen.append(sched.stats["scenarios_solved"] - s0)

    final = sched.plan()
    assert_plans_equal(final, cold_plan(resident, dev, max_group_size))

    m = len(resident)
    scen_per_arrival = float(np.mean(arr_scen))
    # a full re-price would re-solve every pair's kernel probes (the cold
    # count); an arrival's new row is ~cold/n of that
    ratio = cold_scen / max(scen_per_arrival, 1e-9)
    print(f"\n== online churn: n={n} resident, {events} events "
          f"(k<={max_group_size}) on {dev.name} ==")
    print(f"  cold plan          {t_cold:8.3f}s  "
          f"({cold_scen} estimator scenarios)")
    print(f"  arrival event      {np.mean(arr_t):8.3f}s  "
          f"({scen_per_arrival:.0f} scenarios — {ratio:.0f}x fewer "
          f"than a cold re-price)")
    print(f"  departure event    {np.mean(dep_t):8.3f}s  "
          f"({np.mean(dep_scen):.0f} scenarios)")
    # O(n) scenarios with a constant covering the fraction search's
    # coarse grid + refinement on every SLO-failing pair of the new row
    # (the constant follows the active config — the jax backend's denser
    # default grid prices more candidates per pair)
    per_pair = 5 * (sched.search.steps_for(2) - 1
                    + sched.search.refine_levels)
    o_n = scen_per_arrival <= per_pair * (m + 1)
    print(f"  arrival estimator work O(n): "
          f"{'PASS' if o_n else 'FAIL'} "
          f"({scen_per_arrival:.0f} scenarios vs n={m})")
    return {"o_n": o_n, "scen_per_arrival": scen_per_arrival,
            "cold_scen": cold_scen}


def bench_partition_search(dev) -> dict:
    """The k-way slot-fraction search gate: on the SLO-tight decode-heavy
    mix, the k=3 scheduler with the default search must strictly beat the
    legacy fixed-grid pair planner in total gain, via partitioned groups
    of size > 2 (every member within SLO)."""
    mix = decode_heavy_mix(dev)

    t0 = time.perf_counter()
    baseline = cold_plan(mix, dev, max_group_size=2, search=LEGACY_SEARCH)
    t_base = time.perf_counter() - t0
    t0 = time.perf_counter()
    kway = cold_plan(mix, dev, max_group_size=3)
    t_kway = time.perf_counter() - t0

    grown = [p for p in kway.placements
             if len(p.workloads) > 2 and p.slot_fraction]
    ok = (kway.total_gain > baseline.total_gain + 1e-6 and bool(grown)
          and all(p.meets_slo for p in kway.placements))
    print(f"\n== partition search: SLO-tight decode-heavy mix "
          f"({len(mix)} workloads) on {dev.name} ==")
    print(f"  fixed-grid pairs   gain {baseline.total_gain:8.3f}  "
          f"({len(baseline.placements)} placements, "
          f"{len(baseline.solo)} solo, {t_base:.3f}s)")
    print(f"  k-way + search     gain {kway.total_gain:8.3f}  "
          f"({len(kway.placements)} placements, "
          f"{len(kway.solo)} solo, {t_kway:.3f}s)")
    for p in kway.placements:
        fr = {n: round(f, 4) for n, f in p.slot_fraction.items()}
        print(f"    {'+'.join(p.workloads):32s} fractions {fr or 'full'}")
    print(f"  partitioned k-way groups beat fixed-grid pairs: "
          f"{'PASS' if ok else 'FAIL'}")
    return {
        "baseline_gain": baseline.total_gain,
        "kway_gain": kway.total_gain,
        "kway_groups": [
            {"workloads": p.workloads, "fractions": p.slot_fraction,
             "gain": p.throughput_gain} for p in kway.placements],
        "pass": ok,
    }


def bench_solver(dev, partition_gain: float, n_parity: int = 10_000) -> dict:
    """The jax solver-backend gate (ISSUE 8): numpy/jax parity at 1e-9
    on a mixed-width scenario sweep, a batch-size throughput sweep, and
    the denser jax-default fraction search matching the partition gate.

    The speedup gate compares the warmed jax path against the DEPLOYED
    numpy baseline — `estimate_batch` end-to-end on mixed scenarios, the
    ~28k solves/s this repo's schedulers actually paid before ISSUE 8
    (the raw dense solve_batch-vs-solve_batch ratio is recorded too)."""
    try:
        from repro.core import set_solver_backend, solver_backend  # noqa
        from repro.core import estimator_jax  # noqa: F401
    except (ImportError, RuntimeError) as e:
        print(f"\n== solver backend: jax unavailable ({e}) ==")
        return {"available": False, "pass": False}
    from repro.core.estimator import solve_batch, solve_scenarios
    from repro.core.profile import ProfileMatrix
    from repro.core.scenario import Scenario

    rng = np.random.default_rng(0)

    # -- parity: mixed-width (ragged) scenarios through the padded path --
    kernels = random_scenarios(rng, n_parity, dev)
    scens = [Scenario(tuple(sc)) for sc in kernels]
    r_np = solve_scenarios(scens, dev)
    with solver_backend("jax"):
        r_jx = solve_scenarios(scens, dev)
    parity = 0.0
    parity_ok = True
    for field in ("speeds", "slowdowns", "axis_load"):
        a, b = getattr(r_np, field), getattr(r_jx, field)
        fin = np.isfinite(a)
        parity_ok &= bool((np.isfinite(b) == fin).all())
        err = (float((np.abs(a[fin] - b[fin])
                      / (1.0 + np.abs(a[fin]))).max()) if fin.any() else 0.0)
        parity = max(parity, err)
        parity_ok &= bool(np.allclose(b[fin], a[fin], rtol=TOL, atol=TOL))
    parity_ok &= bool((r_np.bottleneck == r_jx.bottleneck).all())
    parity_ok &= bool((r_np.feasible_slots == r_jx.feasible_slots).all())

    # -- deployed numpy baseline: what schedulers paid pre-ISSUE 8 --
    base_n = min(1000, n_parity)
    t_dep, _ = _best_of(lambda: estimate_batch(kernels[:base_n], dev))
    deployed = base_n / t_dep

    # -- batch-size sweep: raw dense solve_batch, numpy vs warmed jax --
    profs = [random_profile(rng, f"sv{i}", dev) for i in range(64)]
    pm = ProfileMatrix.from_profiles(profs)
    sweep = {}
    print(f"\n== solver backend: numpy vs jax on {dev.name} "
          f"(deployed numpy baseline {deployed:,.0f} solves/s) ==")
    print(f"  parity sweep       {n_parity} mixed-width scenarios, "
          f"max rel err {parity:.1e}: {'PASS' if parity_ok else 'FAIL'}")
    for S in (256, 1024, 4096, 16384):
        idx = rng.integers(0, len(profs), (S, 4))
        t_np, _ = _best_of(lambda: solve_batch(pm, idx, dev))
        with solver_backend("jax"):
            solve_batch(pm, idx, dev)            # warm the trace
            t_jx, _ = _best_of(lambda: solve_batch(pm, idx, dev))
        sweep[S] = {"numpy_solves_per_s": S / t_np,
                    "jax_solves_per_s": S / t_jx,
                    "raw_speedup": t_np / t_jx,
                    "speedup_vs_deployed": (S / t_jx) / deployed}
        print(f"  batch {S:>6}       numpy {S / t_np:>9,.0f}/s   "
              f"jax {S / t_jx:>9,.0f}/s   raw {t_np / t_jx:4.1f}x   "
              f"vs deployed {sweep[S]['speedup_vs_deployed']:5.1f}x")
    speedup = max(v["speedup_vs_deployed"] for s, v in sweep.items()
                  if s >= 4096)

    # -- denser jax-default fraction search: gain >= the partition gate --
    mix = decode_heavy_mix(dev)
    with solver_backend("jax"):
        t0 = time.perf_counter()
        kway = cold_plan(mix, dev, max_group_size=3)
        t_dense = time.perf_counter() - t0
    dense_gain = kway.total_gain
    dense_ok = dense_gain >= partition_gain - 1e-9
    print(f"  dense search gain  {dense_gain:.3f} vs partition gate "
          f"{partition_gain:.3f} ({t_dense:.2f}s incl. jit warmup): "
          f"{'PASS' if dense_ok else 'FAIL'}")
    ok = parity_ok and speedup >= 10 and dense_ok
    print(f"  jax >= 10x deployed numpy at batch >= 4096: "
          f"{'PASS' if speedup >= 10 else 'FAIL'} ({speedup:.1f}x)")
    return {
        "available": True,
        "parity_scenarios": n_parity,
        "parity_max_rel_err": parity,
        "parity_pass": bool(parity_ok),
        "numpy_deployed_solves_per_s": deployed,
        "batch_sweep": {str(s): v for s, v in sweep.items()},
        "speedup_vs_deployed": speedup,
        "dense_search_gain": dense_gain,
        "dense_search_wall_s": t_dense,
        "pass": bool(ok),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small n, fewer scenarios; writes "
                         "BENCH_planner.json unless --json overrides it")
    ap.add_argument("--json", type=str, default=None,
                    help="write a machine-readable result summary to this "
                         "path (plan latency, scenarios/arrival, partition-"
                         "search gate; implied as BENCH_planner.json by "
                         "--quick)")
    ap.add_argument("--n", type=int, nargs="*", default=None,
                    help="workload counts to plan (default 16 64 256 1024)")
    ap.add_argument("--scenarios", type=int, default=None,
                    help="estimator batch size (default 1000)")
    ap.add_argument("--seed-cap", type=int, default=None,
                    help="largest n at which the seed planner actually runs "
                         "(beyond: extrapolated; default 256, quick 64)")
    ap.add_argument("--churn-n", type=int, default=256,
                    help="resident workloads in the online-churn bench")
    ap.add_argument("--churn-events", type=int, default=64,
                    help="arrive/leave events in the online-churn bench")
    args = ap.parse_args(argv)

    if args.quick:
        ns = args.n or [16, 64]
        n_scen = args.scenarios or 250
        seed_cap = args.seed_cap if args.seed_cap is not None else 64
    else:
        ns = args.n or [16, 64, 256, 1024]
        n_scen = args.scenarios or 1000
        seed_cap = args.seed_cap if args.seed_cap is not None else 256

    batch_speedup = bench_estimator(n_scen, TPU_V5E)
    planner = bench_planner(ns, seed_cap, TPU_V5E)
    plan_speedups = planner["speedups"]
    churn = bench_churn(args.churn_n, args.churn_events, TPU_V5E)
    partition = bench_partition_search(TPU_V5E)
    solver = bench_solver(TPU_V5E, partition["kway_gain"])

    print("\n== acceptance ==")
    ok_batch = batch_speedup >= 10
    print(f"  estimate_batch >= 10x looped estimate: "
          f"{'PASS' if ok_batch else 'FAIL'} ({batch_speedup:.1f}x)")
    target_n = 256
    if target_n in plan_speedups:
        ok_plan = plan_speedups[target_n] >= 20
        print(f"  cold plan >= 20x seed @ n={target_n}: "
              f"{'PASS' if ok_plan else 'FAIL'} "
              f"({plan_speedups[target_n]:.0f}x)")
    else:
        ok_plan = all(s >= 20 for k, s in plan_speedups.items()
                      if k >= 64 and np.isfinite(s))
        print(f"  cold plan >= 20x seed (n<=cap measured): "
              f"{'PASS' if ok_plan else 'FAIL'} "
              f"({ {k: round(v, 1) for k, v in plan_speedups.items()} })")
    ok_churn = churn["o_n"]
    print(f"  arrival replans with O(n) estimator scenarios: "
          f"{'PASS' if ok_churn else 'FAIL'} "
          f"({churn['scen_per_arrival']:.0f} per arrival vs "
          f"{churn['cold_scen']} cold)")
    ok_part = partition["pass"]
    print(f"  partitioned k-way groups > fixed-grid pairs: "
          f"{'PASS' if ok_part else 'FAIL'} "
          f"({partition['kway_gain']:.3f} vs "
          f"{partition['baseline_gain']:.3f})")
    ok_solver = solver["pass"]
    print(f"  jax solver backend (parity + >= 10x deployed + dense "
          f"search): {'PASS' if ok_solver else 'FAIL'}")

    ok = ok_batch and ok_plan and ok_churn and ok_part and ok_solver
    json_path = args.json or ("BENCH_planner.json" if args.quick else None)
    if json_path:
        payload = {
            "estimator_batch_speedup": batch_speedup,
            "plan_latency_s": {str(n): t
                               for n, t in planner["latency_s"].items()},
            "plan_speedup_vs_seed": {str(n): (None if not np.isfinite(s)
                                              else s)
                                     for n, s in plan_speedups.items()},
            "churn": {"scenarios_per_arrival": churn["scen_per_arrival"],
                      "cold_scenarios": churn["cold_scen"],
                      "o_n_pass": bool(churn["o_n"])},
            "partition_search": partition,
            "solver": solver,
            "acceptance": {"batch": ok_batch, "plan": ok_plan,
                           "churn": ok_churn, "partition": ok_part,
                           "solver": ok_solver, "all": ok},
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"\n  wrote {json_path}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
