"""Verbatim copy of the SEED (pre-vectorization) estimator + planner.

Serves two purposes:
  * the honest baseline that benchmarks/bench_planner.py times the
    batched estimator and incremental planner against;
  * the numerical oracle tests/test_batch_estimator.py checks the
    vectorized solver against (<= 1e-9 agreement).

Do not "improve" this file — it must stay the seed algorithm. The only
edits vs the seed sources are the module header and the scheduler's
imports (it must call the seed `estimate`, not the current one).
"""
# --------------------------------------------------------------------- #
#  seed src/repro/core/estimator.py                                      #
# --------------------------------------------------------------------- #
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.profile import KernelProfile, WorkloadProfile
from repro.core.resources import RESOURCE_AXES, DeviceModel

PER_SLOT_AXES = ("mxu", "vpu", "issue", "smem")
DEVICE_AXES = ("hbm", "l2", "ici")


@dataclass
class ColocationResult:
    speeds: Dict[str, float]            # kernel name -> speed (<=1)
    slowdowns: Dict[str, float]         # kernel name -> 1/speed
    bottleneck: Dict[str, str]          # kernel name -> axis that froze it
    axis_load: Dict[str, float]         # total demanded load per axis
    feasible_slots: bool = True

    def slowdown(self, name: str) -> float:
        return self.slowdowns[name]


# queueing inflation: near-saturated ISSUE slots delay every co-runner's
# instructions even when its own demand fits in the leftover (paper Table 2
# knee; calibrated there, validated out-of-sample on pitfall 2). Mild HBM
# latency inflation mirrors Table 1's sub-saturation slowdowns.
_INFLATION = {"issue": (1.05, 4), "hbm": (0.10, 4)}


def _utilizations(kernels: Sequence[KernelProfile], dev: DeviceModel,
                  slot_fraction: Dict[str, float]) -> Dict[str, Dict[str, float]]:
    total_ws = sum(k.cache_working_set for k in kernels)
    us = {}
    for k in kernels:
        share = (k.cache_working_set / total_ws
                 if total_ws > dev.cache_capacity and k.cache_working_set
                 else 1.0)
        u = k.utilization(dev, cache_share=share)
        frac = slot_fraction.get(k.name, 1.0)
        # restricting a kernel to a slot fraction: per-slot axes capacity
        # seen by that kernel shrinks -> its relative demand grows
        if frac < 1.0:
            for r in PER_SLOT_AXES:
                u[r] = u[r] / max(frac, 1e-6)
        us[k.name] = u
    return us


def estimate(kernels: Sequence[KernelProfile], dev: DeviceModel,
             slot_fraction: Optional[Dict[str, float]] = None
             ) -> ColocationResult:
    """Steady-state speeds + total slowdowns for concurrent kernels.

    slowdown_k = (t_col_k / t_iso_k) / s_k x inflation, where t_col uses
    the COLOCATED cache share (pollution grows demand), s_k is the
    water-filled speed, and inflation is the near-saturation queueing term.
    """
    slot_fraction = slot_fraction or {}
    names = [k.name for k in kernels]
    # cache model: isolated residency is proportional (min(1, C/ws));
    # colocated STREAMING residency has a thrash cliff — once the combined
    # working set exceeds capacity, interleaved streams evict each other
    # before reuse (paper Fig. 3's 16MB peak), so hits collapse.
    total_ws = sum(k.cache_working_set for k in kernels)
    resident_col = 0.0 if total_ws > dev.cache_capacity else 1.0
    us = {}
    t_iso, t_col = {}, {}
    for k in kernels:
        share = resident_col if (len(kernels) > 1 and k.cache_working_set) \
            else min(1.0, dev.cache_capacity / max(k.cache_working_set, 1.0)) \
            if k.cache_working_set else 1.0
        u = k.utilization(dev, cache_share=share)
        frac = slot_fraction.get(k.name, 1.0)
        if frac < 1.0:
            for r in PER_SLOT_AXES:
                u[r] = u[r] / max(frac, 1e-6)
        us[k.name] = u
        t_iso[k.name] = k.isolated_time(dev, cache_share=1.0)
        t_col[k.name] = k.isolated_time(dev, cache_share=share)

    speeds: Dict[str, float] = {n: 1.0 for n in names}
    frozen: Dict[str, str] = {n: "none" for n in names}
    axis_load = {r: sum(us[n][r] for n in names) for r in RESOURCE_AXES}

    # per-axis max-min water-filling: on each oversubscribed axis, only
    # kernels demanding MORE than the fair rate are throttled (a 0.14-IPC
    # copy keeps its slots next to a 3.99-IPC hog; both hogs split evenly)
    active = set(names)
    used = {r: 0.0 for r in RESOURCE_AXES}
    for _ in range(len(names) + len(RESOURCE_AXES)):
        worst_axis, worst_ratio = None, 1.0 + 1e-9
        for r in RESOURCE_AXES:
            dem = sum(speeds[n] * us[n][r] for n in active)
            cap = max(1.0 - used[r], 1e-9)
            if dem / cap > worst_ratio:
                worst_axis, worst_ratio = r, dem / cap
        if worst_axis is None:
            break
        if worst_axis == "smem":
            # bank-conflict serialization throttles EVERY user equally
            # (paper Fig. 4: even low-smem-util GEMMs slow down)
            s = 1.0 / worst_ratio
            for n in list(active):
                if speeds[n] * us[n][worst_axis] > 1e-12:
                    speeds[n] *= s
                    frozen[n] = worst_axis
                    active.discard(n)
                    for r in RESOURCE_AXES:
                        used[r] += speeds[n] * us[n][r]
            continue
        # max-min rate cap theta on worst_axis: sum min(u_n, theta) = cap
        users = sorted(active, key=lambda n: speeds[n] * us[n][worst_axis])
        cap = max(1.0 - used[worst_axis], 1e-9)
        remaining_cap = cap
        remaining_users = [n for n in users
                           if speeds[n] * us[n][worst_axis] > 1e-12]
        theta = None
        for idx, n in enumerate(remaining_users):
            d = speeds[n] * us[n][worst_axis]
            even = remaining_cap / (len(remaining_users) - idx)
            if d <= even:
                remaining_cap -= d
            else:
                theta = even
                break
        if theta is None:
            break
        for n in remaining_users:
            d = speeds[n] * us[n][worst_axis]
            if d > theta:
                scale = theta / d
                speeds[n] *= scale
                frozen[n] = worst_axis
                active.discard(n)
                for r in RESOURCE_AXES:
                    used[r] += speeds[n] * us[n][r]

    # queueing inflation on near-saturated latency-sensitive axes: applies
    # to MINORITY users of the axis (the majority owner is fluid-limited)
    slowdowns = {}
    for n in names:
        base = (t_col[n] / max(t_iso[n], 1e-12)) / max(speeds[n], 1e-9)
        infl = 1.0
        for axis, (gamma, p) in _INFLATION.items():
            u_n = us[n].get(axis, 0.0)
            rho = min(1.0, sum(speeds[m] * us[m][axis] for m in names))
            if (frozen.get(n) == axis or u_n <= 0.01
                    or u_n >= 0.5 * max(rho, 1e-9)):
                continue
            infl += gamma * rho ** p
        slowdowns[n] = base * infl

    slots_needed = sum(k.slots_needed for k in kernels)
    return ColocationResult(
        speeds=speeds,
        slowdowns=slowdowns,
        bottleneck=frozen,
        axis_load=axis_load,
        feasible_slots=slots_needed <= dev.n_slots or slots_needed == 0,
    )


def pairwise_slowdown(a: KernelProfile, b: KernelProfile, dev: DeviceModel,
                      slot_fraction: Optional[Dict[str, float]] = None
                      ) -> Tuple[float, float]:
    r = estimate([a, b], dev, slot_fraction)
    return r.slowdown(a.name), r.slowdown(b.name)


def colocation_speedup(a: KernelProfile, b: KernelProfile,
                       dev: DeviceModel) -> float:
    """Paper Table 3 metric: sequential time / colocated makespan."""
    ta, tb = a.isolated_time(dev), b.isolated_time(dev)
    r = estimate([a, b], dev)
    # fluid makespan: run colocated until the shorter finishes, remainder solo
    ra = ta / max(r.speeds[a.name], 1e-9)
    rb = tb / max(r.speeds[b.name], 1e-9)
    first = min(ra, rb)
    if ra <= rb:
        done_frac = first * r.speeds[b.name] / tb
        makespan = first + (1 - done_frac) * tb
    else:
        done_frac = first * r.speeds[a.name] / ta
        makespan = first + (1 - done_frac) * ta
    return (ta + tb) / makespan


def workload_slowdown(w: WorkloadProfile, others: Sequence[KernelProfile],
                      dev: DeviceModel,
                      slot_fraction: Optional[Dict[str, float]] = None
                      ) -> float:
    """Average slowdown of workload `w` when each of its kernels runs
    against the (steady) background kernels — per-kernel granularity."""
    tot_iso = tot_col = 0.0
    for k in w.kernels:
        t = k.isolated_time(dev) * k.duration_weight
        r = estimate([k, *others], dev, slot_fraction)
        tot_iso += t
        tot_col += t * r.slowdown(k.name)
    return tot_col / max(tot_iso, 1e-12)

# --------------------------------------------------------------------- #
#  seed src/repro/core/scheduler.py (estimator calls bound to the seed   #
#  implementations above)                                                #
# --------------------------------------------------------------------- #


@dataclass
class Placement:
    workloads: List[str]
    slot_fraction: Dict[str, float]
    predicted_slowdown: Dict[str, float]
    meets_slo: bool
    throughput_gain: float       # vs running members serially

    def __repr__(self):
        mems = " + ".join(self.workloads)
        slow = ", ".join(f"{k}:{v:.2f}x" for k, v in self.predicted_slowdown.items())
        return (f"<Placement [{mems}] slow=({slow}) "
                f"gain={self.throughput_gain:.2f} slo_ok={self.meets_slo}>")


def _rep_kernel(w: WorkloadProfile, dev: DeviceModel) -> KernelProfile:
    """Time-weighted aggregate kernel used for quick pair screening."""
    u = w.mixed_utilization(dev)
    t = w.total_time(dev)
    return KernelProfile(w.name, demand={
        r: u[r] * dev.capacity(r) * t for r in u})


def evaluate_pair(a: WorkloadProfile, b: WorkloadProfile, dev: DeviceModel,
                  slot_fraction: Optional[Dict[str, float]] = None
                  ) -> Placement:
    ra = workload_slowdown(a, [_rep_kernel(b, dev)], dev, slot_fraction)
    rb = workload_slowdown(b, [_rep_kernel(a, dev)], dev, slot_fraction)
    slows = {a.name: ra, b.name: rb}
    ta, tb = a.total_time(dev), b.total_time(dev)
    serial = ta + tb
    colocated = max(ta * ra, tb * rb)
    gain = serial / max(colocated, 1e-12)
    return Placement([a.name, b.name], slot_fraction or {}, slows,
                     ra <= a.slo_slowdown and rb <= b.slo_slowdown, gain)


def evaluate_pair_partitioned(a: WorkloadProfile, b: WorkloadProfile,
                              dev: DeviceModel,
                              fractions: Sequence[float] = (0.25, 0.5, 0.75)
                              ) -> Placement:
    """Try full sharing first, then slot partitions (green contexts)."""
    best = evaluate_pair(a, b, dev)
    if best.meets_slo:
        return best
    for f in fractions:
        cand = evaluate_pair(a, b, dev, {a.name: f, b.name: 1.0 - f})
        if cand.meets_slo and cand.throughput_gain > (best.throughput_gain
                                                      if best.meets_slo else 0):
            best = cand
    return best


@dataclass
class Plan:
    placements: List[Placement]
    solo: List[str]

    @property
    def total_gain(self) -> float:
        n_works = sum(len(p.workloads) for p in self.placements) + len(self.solo)
        packed = len(self.placements) + len(self.solo)
        return n_works / max(packed, 1)


def plan_colocation(workloads: Sequence[WorkloadProfile], dev: DeviceModel,
                    allow_partition: bool = True) -> Plan:
    """Greedy max-gain SLO-feasible pairing."""
    remaining = {w.name: w for w in workloads}
    placements: List[Placement] = []
    while len(remaining) >= 2:
        names = list(remaining)
        best: Optional[Placement] = None
        for i in range(len(names)):
            for j in range(i + 1, len(names)):
                a, b = remaining[names[i]], remaining[names[j]]
                p = (evaluate_pair_partitioned(a, b, dev) if allow_partition
                     else evaluate_pair(a, b, dev))
                if p.meets_slo and (best is None
                                    or p.throughput_gain > best.throughput_gain):
                    best = p
        if best is None or best.throughput_gain <= 1.0:
            break
        placements.append(best)
        for n in best.workloads:
            remaining.pop(n)
    return Plan(placements, sorted(remaining))
