"""Benchmark harness: one function per paper table/figure plus the
TPU-native suites. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import bench_roofline, paper_tables, tpu_native

    suites = (paper_tables.ALL + tpu_native.ALL + bench_roofline.ALL)
    print("name,us_per_call,derived")
    failures = 0
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{fn.__name__},0.0,ERROR:{e}", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
