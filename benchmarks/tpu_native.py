"""TPU-native benchmarks: the paper's methodology applied to this
framework's own workloads (dry-run-derived profiles on the v5e model),
the Pallas stressor suite, and the serving engine's interference-aware
scheduling.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import List, Tuple

import numpy as np

from repro.core import (TPU_V5E, ColocationScheduler, WorkloadProfile,
                        sensitivity_batch)
from repro.core.profile import from_dryrun_json

Row = Tuple[str, float, str]
RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def stressor_suite(repeats: int = 5) -> List[Row]:
    """Wall-time of the Pallas microbenchmark suite (interpret mode on
    CPU; on TPU the same calls compile to Mosaic).  Each kernel is timed
    ``repeats`` times through the shared ``median_iqr_time`` timer
    (median + IQR — one outlier dispatch no longer skews the row; the
    calib Pallas backend measures with the same timer)."""
    import jax
    import jax.numpy as jnp
    from repro.calib.measure import median_iqr_time
    from repro.kernels import stressors

    rows = []
    a = jax.random.normal(jax.random.PRNGKey(0), (2, 128, 128), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.float32) * .1
    x = jax.random.normal(jax.random.PRNGKey(2), (512, 128), jnp.float32)

    cases = [
        ("stress_mxu_iters8", lambda: stressors.stress_mxu(a, b, iters=8, interpret=True)),
        ("stress_vpu_ilp4", lambda: stressors.stress_vpu(x, iters=8, ilp=4, interpret=True)),
        ("stress_hbm_copy", lambda: stressors.stress_hbm(x, interpret=True)),
        ("stress_vmem_stride8", lambda: stressors.stress_vmem(x, iters=8, stride=8, interpret=True)),
    ]
    for name, fn in cases:
        med_s, iqr_s = median_iqr_time(fn, repeats=repeats, warmup=1)
        rows.append((name, med_s * 1e6,
                     f"interpret-mode|median_of={repeats}"
                     f"|iqr_us={iqr_s * 1e6:.1f}"))
    return rows


def phase_sensitivity() -> List[Row]:
    """Sensitivity fingerprint of each arch x shape phase (dry-run) — all
    phases fingerprinted in ONE batched estimator solve."""
    recs, profs = [], []
    for f in sorted(RESULTS.glob("*__pod1.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            continue
        recs.append(rec)
        profs.append(from_dryrun_json(rec))
    if not profs:
        return []
    t0 = time.perf_counter()
    reps = sensitivity_batch(profs, TPU_V5E)
    us_each = (time.perf_counter() - t0) * 1e6 / len(profs)
    rows = []
    for rec, rep in zip(recs, reps):
        top = rep.ranked()[:2]
        rows.append((f"sensitivity_{rec['arch']}_{rec['shape']}", us_each,
                     f"dominant={top[0]}:{rep.scores[top[0]]:.2f}x"
                     f"|second={top[1]}:{rep.scores[top[1]]:.2f}x"))
    return rows


def colocation_plan() -> List[Row]:
    """Paper §5.1: plan pairings across this framework's phases."""
    works = []
    for f in sorted(RESULTS.glob("*__pod1.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped") or rec["shape"] not in ("prefill_32k",
                                                      "decode_32k"):
            continue
        p = from_dryrun_json(rec)
        works.append(WorkloadProfile(p.name, (p,), slo_slowdown=1.3))
    if not works:
        return [("colocation_plan", 0.0, "no-dryrun-artifacts")]
    t0 = time.perf_counter()
    sched = ColocationScheduler(TPU_V5E)
    for w in works[:12]:
        sched.submit(w)
    plan = sched.plan()
    us = (time.perf_counter() - t0) * 1e6
    pairs = "; ".join("+".join(p.workloads) for p in plan.placements[:4])
    return [("colocation_plan_12phases", us,
             f"pairs={len(plan.placements)}|solo={len(plan.solo)}|{pairs}")]


def serve_chunked_vs_serial() -> List[Row]:
    """Engine HOL mitigation (paper §4.2 takeaway): TBT gap of the decode
    batch while a long prompt prefills, serial vs interference-aware."""
    from repro.configs.registry import get_config, tiny_config
    from repro.serve import Engine, EngineConfig

    cfg = tiny_config(get_config("qwen3-1.7b"))
    out = []
    for mode in ("serial", "interference_aware"):
        eng = Engine(cfg, ecfg=EngineConfig(max_slots=4, max_len=640,
                                            prefill_chunk=64, mode=mode))
        eng.submit(list(range(1, 17)), max_new=24)       # short: decodes
        eng.run_until_done(max_steps=6)                  # warm decode
        eng.submit(list(range(1, 513)), max_new=4)       # long prompt
        t0 = time.perf_counter()
        eng.run_until_done()
        us = (time.perf_counter() - t0) * 1e6
        decode_ts = [e.t for e in eng.events if e.kind == "decode"]
        gaps = np.diff(decode_ts) * 1e3
        worst = float(np.max(gaps)) if len(gaps) else 0.0
        chunks = [e.detail["chunk"] for e in eng.events
                  if e.kind == "prefill_chunk"]
        out.append((f"serve_hol_{mode}", us,
                    f"worst_decode_gap={worst:.1f}ms|chunks={chunks[:8]}"))
    return out


ALL = [stressor_suite, phase_sensitivity, colocation_plan,
       serve_chunked_vs_serial]
