"""Roofline benchmark: convert dry-run artifacts into the §Roofline table
(one row per arch x shape x mesh) and per-kind efficiency summaries."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import roofline

Row = Tuple[str, float, str]


def roofline_rows() -> List[Row]:
    t0 = time.perf_counter()
    rows = roofline.load_results("results/dryrun")
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    out = []
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.mesh)):
        out.append((f"roofline_{r.arch}_{r.shape}_{r.mesh}", us,
                    f"bound={r.bound}|compute={r.compute_s * 1e3:.1f}ms"
                    f"|mem={r.memory_s * 1e3:.1f}ms"
                    f"|coll={r.collective_s * 1e3:.1f}ms"
                    f"|useful={r.useful_ratio:.2f}"
                    f"|frac={r.roofline_frac:.3f}"))
    return out


ALL = [roofline_rows]
