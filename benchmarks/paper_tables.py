"""Reproduction of every measured table/figure in the paper via the
interference estimator + the paper's reported NCU metrics, on the
matching GPU resource model. Each function returns rows
(name, us_per_call, derived) where `derived` is "predicted|measured".
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import H100, RTX3090, KernelProfile, colocation_speedup, estimate
from repro.core.resources import RESOURCE_AXES

Row = Tuple[str, float, str]


def _prof(dev, name, duration=1.0, ws=0.0, hit=0.0, **axes) -> KernelProfile:
    d = {r: 0.0 for r in RESOURCE_AXES}
    for ax, frac in axes.items():
        d[ax] = frac * dev.capacity(ax) * duration
    return KernelProfile(name, demand=d, duration=duration,
                         cache_working_set=ws, cache_hit_fraction=hit)


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# ------------------------------------------------------------------ #
#  §3 Pitfall 1 (occupancy): colocated compute kernels + SM restrict  #
# ------------------------------------------------------------------ #
def pitfall1() -> List[Row]:
    rows = []
    k1 = _prof(H100, "c1", issue=0.99, vpu=0.5)
    k2 = _prof(H100, "c2", issue=0.99, vpu=0.5)
    r, us = _timed(lambda: estimate([k1, k2], H100))
    rows.append(("pitfall1_colocate_2x_compute", us,
                 f"pred={r.slowdowns['c1']:.2f}x|paper=1.73x"))
    r, us = _timed(lambda: estimate(
        [k1], H100, slot_fraction={"c1": 0.0625}))
    rows.append(("pitfall1_restrict_to_occupancy_6.25pct", us,
                 f"pred={r.slowdowns['c1']:.2f}x|paper=8.57x"))
    return rows


# ------------------------------------------------------------------ #
#  §3 Pitfall 2 (arith-intensity): compute hog x copy                 #
# ------------------------------------------------------------------ #
def pitfall2() -> List[Row]:
    comp = _prof(H100, "compute", issue=0.99, vpu=0.5)
    copy = _prof(H100, "copy", issue=0.57 / 4, hbm=0.75, l2=0.4)
    r, us = _timed(lambda: estimate([comp, copy], H100))
    return [("pitfall2_copy_under_ipc_hog", us,
             f"pred={r.slowdowns['copy']:.2f}x|paper=2.0x")]


# ------------------------------------------------------------------ #
#  §4.2 Fig 2: block-scheduler head-of-line blocking                  #
# ------------------------------------------------------------------ #
def fig2_hol() -> List[Row]:
    """Llama3-8B decode (P90 TBT 7.53ms) + 10ms resource-hogging sleep
    kernel. Monolithic scheduling serializes (paper: 16.56ms); per-kernel
    granularity with an SM-resource-aware scheduler avoids the stall."""
    tbt_iso = 7.53e-3
    sleep_ms = 10.0
    # serialized: decode waits for the sleep kernel's residual duration
    t0 = time.perf_counter()
    pred_serial = tbt_iso + 0.9 * sleep_ms * 1e-3   # ~overlap of 1 kernel
    # fine-grained: the scheduler interleaves decode kernels between the
    # sleeper's blocks; contention only on issue slots (negligible)
    sleep_prof = _prof(H100, "sleep", issue=0.01)
    dec = _prof(H100, "decode", hbm=0.55, issue=0.10, duration=tbt_iso)
    r = estimate([dec, sleep_prof], H100)
    pred_fine = tbt_iso * r.slowdowns["decode"]
    us = (time.perf_counter() - t0) * 1e6
    return [("fig2_hol_monolithic", us,
             f"pred={pred_serial * 1e3:.2f}ms|paper=16.56ms"),
            ("fig2_hol_per_kernel_sched", us,
             f"pred={pred_fine * 1e3:.2f}ms|paper_iso=7.53ms")]


# ------------------------------------------------------------------ #
#  §4.3 Fig 3: L2 pollution sweep (two copy kernels)                  #
# ------------------------------------------------------------------ #
def fig3_l2() -> List[Row]:
    paper = {4: 1.0, 8: 1.0, 16: 2.15, 26: 1.3, 48: 1.12}
    rows = []
    for mb, want in paper.items():
        ws = 2 * mb * 1e6
        mk = lambda n: _prof(H100, n, hbm=0.94, l2=0.45, issue=0.2,
                             ws=ws, hit=0.95)
        r, us = _timed(lambda: estimate([mk("a"), mk("b")], H100))
        rows.append((f"fig3_l2_pollution_{mb}MB", us,
                     f"pred={r.slowdowns['a']:.2f}x|paper={want}x"))
    return rows


# ------------------------------------------------------------------ #
#  §4.3 Table 1: decode TBT vs copy-kernel bandwidth                  #
# ------------------------------------------------------------------ #
def table1_membw() -> List[Row]:
    decode = _prof(H100, "decode", hbm=0.55, issue=0.10)
    paper = {34: (0.27, 17.6), 68: (0.51, 18.38),
             102: (0.69, 19.92), 136: (0.81, 22.0)}
    rows = []
    for blocks, (bw, tbt) in paper.items():
        copy = _prof(H100, "copy", hbm=bw, issue=0.05)
        r, us = _timed(lambda: estimate([decode, copy], H100))
        rows.append((f"table1_membw_{blocks}blocks", us,
                     f"pred={16.9 * r.slowdowns['decode']:.1f}ms|paper={tbt}ms"))
    return rows


# ------------------------------------------------------------------ #
#  §4.4.1 Fig 4: shared-memory bank-conflict interference             #
# ------------------------------------------------------------------ #
def fig4_smem() -> List[Row]:
    gemm_hi = _prof(H100, "gemm1024", mxu=0.35, smem=0.75, issue=0.4)
    gemm_lo = _prof(H100, "gemm2048", mxu=0.55, smem=0.40, issue=0.3)
    rows = []
    for name, gemm, paper in (("dim1024", gemm_hi, 3.75),
                              ("dim2048", gemm_lo, 1.79)):
        st = _prof(H100, "strided32", smem=0.95, issue=0.3)
        r, us = _timed(lambda: estimate([gemm, st], H100))
        rows.append((f"fig4_smem_32way_{name}", us,
                     f"pred={r.slowdowns[gemm.name]:.2f}x|paper={paper}x"))
    return rows


# ------------------------------------------------------------------ #
#  §4.4.2 Table 2: Gemma3-1B decode TBT under IPC sweep (RTX3090)     #
# ------------------------------------------------------------------ #
def table2_ipc() -> List[Row]:
    decode = _prof(RTX3090, "decode", hbm=0.5, issue=0.55 / 4)
    paper = {"S1": (1.18, 6.23), "S2": (2.06, 6.56), "S4": (3.45, 12.52)}
    rows = []
    for s, (ipc, tbt) in paper.items():
        st = _prof(RTX3090, s, issue=ipc / 4, vpu=ipc / 8)
        r, us = _timed(lambda: estimate([decode, st], RTX3090))
        rows.append((f"table2_ipc_{s}_ipc{ipc}", us,
                     f"pred={6.08 * r.slowdowns['decode']:.2f}ms|paper={tbt}ms"))
    return rows


# ------------------------------------------------------------------ #
#  §4.4.3 Table 3: FP64 pipeline colocation speedup                   #
# ------------------------------------------------------------------ #
def table3_pipeline() -> List[Row]:
    paper = {"S1": (0.2422, 1.93), "S2": (0.4771, 1.87),
             "S3": (0.6942, 1.33), "S4": (0.9068, 1.03)}
    rows = []
    for s, (util, want) in paper.items():
        a = _prof(H100, "a", vpu=util, issue=0.49)
        b = _prof(H100, "b", vpu=util, issue=0.49)
        got, us = _timed(lambda: colocation_speedup(a, b, H100))
        rows.append((f"table3_fp64_{s}_util{util:.0%}", us,
                     f"pred={got:.2f}x|paper={want}x"))
    return rows


ALL = [pitfall1, pitfall2, fig2_hol, fig3_l2, table1_membw, fig4_smem,
       table2_ipc, table3_pipeline]
