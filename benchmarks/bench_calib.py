"""Calibration gate: the measure -> fit -> validate -> drift loop holds.

Two CI contracts over `repro.calib`:

1. **Synthetic round-trip** — hide perturbed ground-truth KernelProfiles
   behind the deterministic synthetic backend, run the §4 stressor×victim
   sweep, fit profiles from the observed slowdowns alone, then score the
   fit on HELD-OUT k-way mixes (victim+cohort colocations and off-grid
   stressor intensities the fitter never saw).  Gate: max relative
   slowdown-prediction error <= 5%.  The whole pipeline is seeded, so
   the calibration report must also be bit-identical across two runs.

2. **Drift monitor** — replay a fixed sim trace with a mid-trace
   profile shift injected into one colocated SLO tenant (its TRUE
   demand inflates past its roofline while the fleet keeps believing
   the original).  Gate: exactly that tenant is flagged and re-fit, the
   clean same-seed twin trace produces zero flags, calib counters
   surface in fleet stats and the sim report, and the shifted run's
   report is bit-identical across two runs.

  PYTHONPATH=src python benchmarks/bench_calib.py          # full
  PYTHONPATH=src python benchmarks/bench_calib.py --quick  # CI gate
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.calib import (FitConfig, SyntheticBackend, fit_profiles,
                         fit_report, holdout_mixes, perturb_profile,
                         validate)
from repro.core.fleet import SLO
from repro.core.profile import KernelProfile
from repro.core.resources import TPU_V5E, TPU_V5P
from repro.sim import Simulator, TraceConfig, generate_trace

MAX_REL_ERROR = 0.05         # held-out mix prediction error ceiling
SEED = 2026
SHIFT_T = 30.0               # virtual seconds into the drift trace
DRIFT_TRACE = dict(seed=11, duration=90.0, n_tenants=14, n_bursts=1,
                   churn_fraction=0.0)
DRIFT_DEVICES = 6


# ------------------------------------------------------------------ #
#  Round-trip: hidden truth -> sweep -> fit -> held-out validation     #
# ------------------------------------------------------------------ #
def base_kernels(dev) -> dict:
    """A diverse victim set: bandwidth-bound decode, matmul-bound gemm,
    vector scan, a cache-resident attention-like kernel, and a
    scratch/interconnect-leaning collective — one per paper workload
    archetype, all duration-bound like the registry profiles."""
    C = dev.capacity
    return {
        "decode": KernelProfile("decode", demand={
            "hbm": 0.70 * C("hbm"), "mxu": 0.25 * C("mxu"),
            "issue": 0.30 * C("issue")}, duration=1.0),
        "gemm": KernelProfile("gemm", demand={
            "mxu": 0.85 * C("mxu"), "hbm": 0.20 * C("hbm")}, duration=1.0),
        "scan": KernelProfile("scan", demand={
            "vpu": 0.75 * C("vpu"), "issue": 0.45 * C("issue"),
            "smem": 0.30 * C("smem"), "hbm": 0.25 * C("hbm")},
            duration=1.0),
        "attn": KernelProfile("attn", demand={
            "hbm": 0.60 * C("hbm"), "vpu": 0.30 * C("vpu")}, duration=1.0,
            cache_working_set=0.5 * dev.cache_capacity,
            cache_hit_fraction=0.6),
        "allreduce": KernelProfile("allreduce", demand={
            "ici": 0.65 * C("ici"), "hbm": 0.35 * C("hbm"),
            "issue": 0.20 * C("issue")}, duration=1.0),
    }


def run_roundtrip(seed: int = SEED, dev=TPU_V5E, noise: float = 0.0) -> dict:
    rng = np.random.default_rng(seed)
    truth = {n: perturb_profile(k, rng, scale=0.25, dev=dev)
             for n, k in base_kernels(dev).items()}
    backend = SyntheticBackend(truth, dev, noise=noise, seed=seed + 1)
    t0 = time.perf_counter()
    sweep = backend.run_sweep(sorted(truth))
    fitted = fit_profiles(sweep, FitConfig())
    fit_s = time.perf_counter() - t0
    mixes = holdout_mixes(sorted(truth), np.random.default_rng(seed + 2))
    report = validate(fitted, backend, mixes)
    return {
        "device": dev.name,
        "noise": noise,
        "n_observations": len(sweep),
        "fit_seconds": fit_s,
        "fit": fit_report(sweep, fitted).to_json(),
        "validation": report.to_json(),
    }


# ------------------------------------------------------------------ #
#  Drift: injected profile shift on a fixed sim trace                  #
# ------------------------------------------------------------------ #
def drift_devices() -> dict:
    return {f"dev{i}": (TPU_V5E if i % 2 else TPU_V5P)
            for i in range(DRIFT_DEVICES)}


def pick_shift_target() -> tuple:
    """Deterministic discovery: run the clean trace once and pick the
    first (sorted device order) long-lived SLO tenant placed in a >=2
    group, with a demand scale that pushes its roofline 1.4x past its
    duration — the regime where a pure demand shift is observable (see
    repro.calib.drift)."""
    trace = generate_trace(TraceConfig(**DRIFT_TRACE))
    sim = Simulator(trace, drift_devices())
    sim.run()
    plan = sim.fleet.plan()
    for did in sorted(plan.placements):
        p = plan.placements[did]
        if len(p.workloads) < 2:
            continue
        for name in p.workloads:
            spec = trace.tenants.get(name)
            if spec is None or spec.priority != SLO \
                    or spec.depart is not None:
                continue
            model = sim.fleet.devices[did].model
            umax = max(spec.profile.mixed_utilization(model).values())
            return name, 1.4 / max(umax, 1e-9)
    raise RuntimeError("drift trace has no colocated SLO tenant to shift")


def run_drift(tenant: str, scale: float) -> dict:
    cfg = TraceConfig(**DRIFT_TRACE,
                      profile_shifts=((SHIFT_T, tenant, scale),))
    sim = Simulator(generate_trace(cfg), drift_devices())
    return sim.run()


def run_clean() -> dict:
    sim = Simulator(generate_trace(TraceConfig(**DRIFT_TRACE)),
                    drift_devices())
    return sim.run()


# ------------------------------------------------------------------ #
#  Gates                                                               #
# ------------------------------------------------------------------ #
def _no_timing(report: dict) -> dict:
    return {k: v for k, v in report.items() if k != "fit_seconds"}


def gate(roundtrip: dict, roundtrip_twin: dict, shifted: dict,
         shifted_twin: dict, clean: dict, tenant: str) -> dict:
    val = roundtrip["validation"]
    calib = shifted["calib"]
    checks = {
        "roundtrip_max_rel_error": val["max_rel_error"] <= MAX_REL_ERROR,
        "roundtrip_deterministic": (_no_timing(roundtrip)
                                    == _no_timing(roundtrip_twin)),
        "drift_flagged": (calib["flags"] >= 1
                          and calib["flagged_tenants"] == [tenant]),
        "drift_refit": calib["refits"] >= 1,
        "drift_no_errors": shifted["fleet"]["event_loop_errors"] == 0,
        "clean_zero_flags": (clean["calib"]["flags"] == 0
                             and clean["calib"]["refits"] == 0
                             and clean["calib"]["flagged_tenants"] == []),
        "clean_observed": clean["calib"]["observations"] > 0,
        "drift_deterministic": shifted == shifted_twin,
    }
    checks["all"] = all(checks.values())
    return checks


def describe(roundtrip: dict, shifted: dict, clean: dict,
             tenant: str, scale: float) -> None:
    val = roundtrip["validation"]
    print("== synthetic round-trip ==")
    print(f"  {roundtrip['n_observations']} sweep observations on "
          f"{roundtrip['device']}, fit in "
          f"{roundtrip['fit_seconds']:.1f}s")
    print(f"  held-out mixes: {val['n_mixes']}, max rel error "
          f"{val['max_rel_error']:.4f} (mean {val['mean_rel_error']:.4f},"
          f" ceiling {MAX_REL_ERROR})")
    worst_axis = max(val["per_axis"], key=lambda a: val["per_axis"][a])
    print(f"  worst axis {worst_axis} "
          f"({val['per_axis'][worst_axis]:.4f}), worst mix "
          f"{val['worst_mix']}")
    print("== drift monitor ==")
    c, cc = shifted["calib"], clean["calib"]
    print(f"  shifted {tenant} x{scale:.1f} at t={SHIFT_T:.0f}s: "
          f"{c['flags']} flags {c['refits']} refits "
          f"(flagged: {', '.join(c['flagged_tenants']) or '-'}), "
          f"{c['observations']} observations")
    print(f"  clean twin: {cc['flags']} flags {cc['refits']} refits, "
          f"{cc['observations']} observations")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI gate; writes BENCH_calib.json unless "
                         "--json overrides it")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args(argv)

    roundtrip = run_roundtrip()
    roundtrip_twin = run_roundtrip()
    tenant, scale = pick_shift_target()
    shifted = run_drift(tenant, scale)
    shifted_twin = run_drift(tenant, scale)
    clean = run_clean()
    describe(roundtrip, shifted, clean, tenant, scale)

    extras = {}
    if not args.quick:
        noisy = run_roundtrip(noise=0.01)
        v5p = run_roundtrip(dev=TPU_V5P)
        print("== variants ==")
        print(f"  1% lognormal noise: max rel error "
              f"{noisy['validation']['max_rel_error']:.4f}")
        print(f"  v5p round-trip: max rel error "
              f"{v5p['validation']['max_rel_error']:.4f}")
        extras = {"noisy": noisy, "v5p": v5p}

    checks = gate(roundtrip, roundtrip_twin, shifted, shifted_twin,
                  clean, tenant)
    print("\n== acceptance ==")
    for name, ok in checks.items():
        if name != "all":
            print(f"  {name}: {'PASS' if ok else 'FAIL'}")

    json_path = args.json or ("BENCH_calib.json" if args.quick else None)
    if json_path:
        payload = {
            "roundtrip": roundtrip,
            "drift": {"tenant": tenant, "scale": scale,
                      "shifted": shifted["calib"],
                      "shifted_fleet": shifted["fleet"],
                      "clean": clean["calib"]},
            "acceptance": checks,
            **extras,
        }
        Path(json_path).write_text(json.dumps(payload, indent=2,
                                              sort_keys=True) + "\n")
        print(f"\n  wrote {json_path}")
    return 0 if checks["all"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
