"""Fleet recovery gate: deterministic fault injection over FleetScheduler.

Replays fixed traces (virtual clock, no sleeps — bit-identical every
run) against a ``FleetScheduler`` and gates the robustness claims:

  1. recovery: after an injected device kill, 100% of SLO workloads are
     re-placed on the survivors; every displaced best-effort workload
     has an explicit "evicted" decision; the fleet never raises out of
     the event loop (stats["errors"] == 0); and the post-recovery online
     fleet plan equals a cold ``FleetScheduler`` plan over the surviving
     devices/workloads at 1e-9 (placements, slowdowns, fractions, gain);
  2. admission: an arrival storm against a bounded queue rejects the
     overflow with explicit decision records and the tracked pool stays
     bounded — no silent unbounded growth;
  3. straggler: a slow device degrades via the EWMA monitor; SLO work
     migrates off it while best-effort may remain.
  4. scale (scoped repair): a 256-device heterogeneous fleet (alternating
     v5e/v5p) under ~64 churn mutations — arrivals, departures, planned
     drains, revives — must repair INCREMENTALLY: p95 devices touched
     per scoped repair <= 16, mean replan latency >= 10x faster than a
     forced full-replay twin, total packed gain within the configured
     divergence epsilon of a cold replay, the placed-SLO set identical
     to the cold replay, and zero event-loop errors.

`--quick` (the CI smoke) runs the same traces — they are already small,
and the scale gate is sized to stay inside the CI budget — and writes
BENCH_fleet.json (recovery latency, evictions, SLO re-placement rate,
online==cold, the scale gate) as a CI artifact next to
BENCH_planner.json.

  PYTHONPATH=src python benchmarks/bench_fleet.py          # full gates
  PYTHONPATH=src python benchmarks/bench_fleet.py --quick  # CI smoke
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from bench_planner import decode_heavy_mix
from repro.core import (TPU_V5E, TPU_V5P, BEST_EFFORT, SLO, FleetConfig,
                        FleetScheduler, KernelProfile, WorkloadProfile)
from repro.core.resources import RESOURCE_AXES
from repro.ft.inject import FakeClock, FaultInjector, arrive, kill, slow, storm

TOL = 1e-9


def fleet_plans_equal(got, want, tol=TOL):
    """FleetPlan equality at tol: same placements (members in order),
    slot fractions, predicted slowdowns, and gains; same UNPLACED set
    (queued + degraded pooled — the queued/degraded split is retry
    history, which a cold fleet by definition does not have)."""
    if set(got.placements) != set(want.placements):
        return False
    for did, a in got.placements.items():
        b = want.placements[did]
        if a.workloads != b.workloads or set(a.slot_fraction) != set(b.slot_fraction):
            return False
        if any(abs(a.slot_fraction[n] - b.slot_fraction[n]) > tol
               for n in a.slot_fraction):
            return False
        if any(abs(a.predicted_slowdown[n] - b.predicted_slowdown[n]) > tol
               for n in a.workloads):
            return False
        if abs(a.throughput_gain - b.throughput_gain) > tol:
            return False
    return (sorted(got.queued + got.degraded)
            == sorted(want.queued + want.degraded))


def cold_fleet(online, dev_models, config):
    """Cold FleetScheduler over the given devices, fed the online
    fleet's tracked pool in arrival order (the recovery-gate contract)."""
    fleet = FleetScheduler(dev_models, config)
    for prof, prio in online.workloads:
        fleet.submit(prof, priority=prio)
    return fleet


# ------------------------------------------------------------------ #
def bench_recovery(dev):
    """The fixed device-kill trace: 4 devices, 4 SLO decodes + 6
    best-effort auxes (10 workloads, 12 slots), kill dev1 at t=8 —
    9 surviving slots force best-effort evictions while every SLO
    workload must re-place."""
    cfg = FleetConfig(max_group_size=3, heartbeat_timeout=3.0,
                      backoff_base=1.0, max_retries=3)
    works = decode_heavy_mix(dev, n_decode=4, n_aux=6)
    decodes, auxes = works[:4], works[4:]
    clock = FakeClock()
    models = {f"dev{i}": dev for i in range(4)}
    fleet = FleetScheduler(models, cfg, clock=clock)
    kill_t = 8.0
    trace = ([arrive(float(i), d, priority=SLO)
              for i, d in enumerate(decodes)]
             + storm(4.0, auxes, priority=BEST_EFFORT)
             + [kill(kill_t, "dev1")])
    FaultInjector(fleet, clock).run(trace, until=30.0)

    plan = fleet.plan()
    slo_names = [w.name for w in decodes]
    slo_rate = plan.placement_rate(slo_names)
    pre_kill_placed = {d.workload for d in fleet.decisions
                      if d.time <= kill_t and d.action == "placed"}
    evicted = [d for d in fleet.decisions if d.action == "evicted"]
    placed_now = plan.placed
    # every best-effort workload that lost its pre-kill placement for
    # good must have an explicit eviction record
    displaced_be = [w.name for w in auxes
                    if w.name in pre_kill_placed and w.name not in placed_now]
    evicted_names = {d.workload for d in evicted}
    evictions_recorded = all(n in evicted_names for n in displaced_be)

    dead_t = next(d.time for d in fleet.decisions
                  if d.action == "device-dead")
    slo_recovered_t = max(
        (d.time for d in fleet.decisions
         if d.time >= dead_t and d.workload in slo_names
         and d.action in ("placed", "migrated")), default=dead_t)
    recovery_latency = slo_recovered_t - kill_t

    survivors = {did: m for did, m in models.items() if did != "dev1"}
    cold = cold_fleet(fleet, survivors, cfg)
    online_eq_cold = fleet_plans_equal(plan, cold.plan())

    res = {
        "slo_replacement_rate": slo_rate,
        "evictions": len(evicted),
        "evictions_recorded": bool(evictions_recorded),
        "recovery_latency_s": recovery_latency,
        "event_loop_errors": fleet.stats["errors"],
        "online_equals_cold": bool(online_eq_cold),
        "migrations": fleet.stats["migrated"],
        "replans": fleet.stats["replans"],
        "scenarios_solved": fleet.stats["scenarios_solved"],
        "decisions": len(fleet.decisions),
    }
    res["pass"] = bool(slo_rate == 1.0 and evictions_recorded
                       and len(evicted) >= 1
                       and fleet.stats["errors"] == 0 and online_eq_cold)
    return res


def bench_admission(dev):
    """Arrival storm vs a bounded queue: one device, queue_limit=2, a
    storm of 8 best-effort workloads on one tick — the overflow must be
    rejected with decision records and the tracked pool stays bounded.
    Also gates storm *batching*: the whole same-tick storm must be
    admitted through ONE deduplicated replay (replans-per-storm == 1,
    not one per arrival)."""
    cfg = FleetConfig(max_group_size=2, queue_limit=2,
                      heartbeat_timeout=3.0)
    works = decode_heavy_mix(dev, n_decode=2, n_aux=8)
    decodes, auxes = works[:2], works[2:]
    clock = FakeClock()
    fleet = FleetScheduler({"dev0": dev}, cfg, clock=clock)
    trace = ([arrive(0.0, d, priority=SLO) for d in decodes]
             + storm(1.0, auxes, priority=BEST_EFFORT))
    replans_at = {}
    def snap(f, now):
        replans_at[now] = f.stats["replans"]
    FaultInjector(fleet, clock, on_tick=snap).run(trace, until=5.0)
    storm_replans = replans_at[1.0] - replans_at[0.0]
    rejected = [d for d in fleet.decisions if d.action == "rejected"]
    tracked = len(fleet)
    bound = 2 * cfg.max_group_size + 2 * cfg.queue_limit  # placed + queues
    res = {
        "storm_size": len(auxes),
        "rejected": len(rejected),
        "tracked_after_storm": tracked,
        "tracked_bound": bound,
        "storm_replans": storm_replans,
        "event_loop_errors": fleet.stats["errors"],
    }
    res["pass"] = bool(len(rejected) >= 1 and tracked <= bound
                       and storm_replans == 1
                       and fleet.stats["errors"] == 0)
    return res


def bench_straggler(dev):
    """A slow device degrades via the EWMA monitor: SLO work must leave
    it; best-effort may stay (degraded devices still take best-effort)."""
    cfg = FleetConfig(max_group_size=3, heartbeat_timeout=3.0)
    works = decode_heavy_mix(dev, n_decode=2, n_aux=2)
    decodes, auxes = works[:2], works[2:]
    clock = FakeClock()
    fleet = FleetScheduler({"dev0": dev, "dev1": dev}, cfg, clock=clock)
    trace = ([arrive(float(i), d, priority=SLO)
              for i, d in enumerate(decodes)]
             + [arrive(2.0, a, priority=BEST_EFFORT) for a in auxes]
             + [slow(4.0, "dev1")])
    FaultInjector(fleet, clock).run(trace, until=10.0)
    plan = fleet.plan()
    slo_on_degraded = [n for n in (w.name for w in decodes)
                       if plan.placed.get(n) == "dev1"]
    res = {
        "device_states": plan.device_states,
        "slo_replacement_rate": plan.placement_rate(
            [w.name for w in decodes]),
        "slo_on_degraded_device": slo_on_degraded,
        "event_loop_errors": fleet.stats["errors"],
    }
    res["pass"] = bool(plan.device_states["dev1"] == "degraded"
                       and not slo_on_degraded
                       and res["slo_replacement_rate"] == 1.0
                       and fleet.stats["errors"] == 0)
    return res


# ------------------------------------------------------------------ #
#  Scale gate: scoped repair on a 256-device heterogeneous fleet      #
# ------------------------------------------------------------------ #
SCALE_DEVICES = 256
SCALE_INIT = 192        # initial tenants (submitted in waves)
SCALE_WAVE = 16
SCALE_CHURN = 64        # churn mutations after the initial load
SCALE_TOUCHED_P95 = 16.0
SCALE_SPEEDUP = 10.0
SCALE_FULL_MUTATIONS = 3   # mutations timed on the forced-full twin


def loose_mix(n, prefix="s"):
    """n loose-SLO (1.5x) workloads, alternating compute- and
    bandwidth-leaning so triples contend mildly on one axis but always
    meet their SLO at full share — the scale gate measures repair
    *width*, not partition-search depth.  Demands are absolute (sized
    off v5e capacities), so the same workload leaves genuinely more
    headroom on a v5p — the heterogeneous greedy sees different prices
    per model."""
    out = []
    for i in range(n):
        if i % 2 == 0:
            u = {"mxu": 0.40, "vpu": 0.05, "issue": 0.06,
                 "hbm": 0.18, "l2": 0.18}
        else:
            u = {"mxu": 0.12, "vpu": 0.04, "issue": 0.05,
                 "hbm": 0.38, "l2": 0.38}
        d = {r: u.get(r, 0.0) * TPU_V5E.capacity(r) for r in RESOURCE_AXES}
        name = f"{prefix}{i}"
        out.append(WorkloadProfile(
            name, (KernelProfile(f"{name}#step", demand=d, duration=1.0),),
            slo_slowdown=1.5))
    return out


def scale_models(n=SCALE_DEVICES):
    """The heterogeneous mix: even devices v5e, odd devices v5p."""
    return {f"dev{i:03d}": (TPU_V5E if i % 2 == 0 else TPU_V5P)
            for i in range(n)}


def _scale_churn(fleet, clock, init, churn):
    """Apply the fixed churn script: per 8-mutation block, 3 arrivals,
    3 departures, one planned drain (decommission) and one revive of
    the oldest drained device — every kind routes its own RepairScope."""
    prios = [SLO, BEST_EFFORT]
    drained = []
    ci = si = 0
    for m in range(SCALE_CHURN):
        step = m % 8
        if step in (0, 2, 4):
            fleet.submit(churn[ci], priority=prios[ci % 2])
            ci += 1
        elif step in (1, 3, 5):
            name = init[si].name
            si += 1
            if name in fleet:
                fleet.remove(name)
        elif step == 6:
            fleet.decommission(f"dev{(m * 5) % SCALE_DEVICES:03d}")
            drained.append(f"dev{(m * 5) % SCALE_DEVICES:03d}")
        else:
            fleet.heartbeat(drained.pop(0))
        clock.advance(1.0)


def bench_scale():
    """256-device churn under scoped repair, gated four ways: repair
    locality (touched p95), replan speedup vs a forced-full twin, the
    bounded-divergence contract vs a cold replay, and exact SLO-set
    agreement with that cold replay."""
    cfg = FleetConfig(max_group_size=3, queue_limit=64,
                      heartbeat_timeout=1e9)
    models = scale_models()
    clock = FakeClock()
    fleet = FleetScheduler(models, cfg, clock=clock)
    init = loose_mix(SCALE_INIT, prefix="s")
    prios = [SLO if i % 2 == 0 else BEST_EFFORT for i in range(SCALE_INIT)]
    for w0 in range(0, SCALE_INIT, SCALE_WAVE):
        fleet.submit_many(list(zip(init[w0:w0 + SCALE_WAVE],
                                   prios[w0:w0 + SCALE_WAVE])))
        clock.advance(1.0)
    n_init = len(fleet.repairs)

    churn = loose_mix(SCALE_CHURN, prefix="c")
    _scale_churn(fleet, clock, init, churn)

    churn_recs = fleet.repairs[n_init:]
    scoped = [r for r in churn_recs if not r.full]
    touched_p95 = float(np.percentile(
        [r.devices_touched for r in scoped], 95)) if scoped else float("inf")
    scoped_lat = float(np.mean([r.latency_s for r in churn_recs]))

    plan = fleet.plan()
    slo_names = [p.name for p, prio in fleet.workloads if prio == SLO]
    slo_rate = plan.placement_rate(slo_names)

    # bounded-divergence contract vs a cold full replay over the same
    # pool and surviving devices (one batched storm = ONE cold replay)
    full_cfg = FleetConfig(max_group_size=3, queue_limit=64,
                           heartbeat_timeout=1e9, repair_mode="full")
    survivors = {did: d.model for did, d in fleet.devices.items()
                 if d.state != "dead"}
    cold = FleetScheduler(survivors, full_cfg)
    cold.submit_many([(p, prio) for p, prio in fleet.workloads])
    cold_plan = cold.plan()
    gain_ratio = (plan.total_gain / cold_plan.total_gain
                  if cold_plan.total_gain > 0 else 1.0)
    slo_sets_match = ({n for n in slo_names if n in plan.placed}
                      == {n for n in slo_names if n in cold_plan.placed})

    # forced-full twin: same fleet and load, repair_mode="full" — time a
    # handful of the same mutation kinds through the cold-replay path
    twin = FleetScheduler(scale_models(), full_cfg, clock=FakeClock())
    twin.submit_many(list(zip(init, prios)))
    n_twin = len(twin.repairs)
    twin.submit(loose_mix(1, prefix="t")[0], priority=BEST_EFFORT)
    twin.remove(init[0].name)
    twin.decommission("dev030")
    full_lat = float(np.mean(
        [r.latency_s for r in twin.repairs[n_twin:][:SCALE_FULL_MUTATIONS]]))
    speedup = full_lat / max(scoped_lat, 1e-12)

    res = {
        "devices": SCALE_DEVICES,
        "device_models": sorted({m.name for m in models.values()}),
        "workloads_final": len(fleet),
        "churn_mutations": SCALE_CHURN,
        "churn_repairs": len(churn_recs),
        "scoped_repairs": fleet.stats["scoped_repairs"],
        "full_replays": fleet.stats["full_replays"],
        "repair_fallbacks": fleet.stats["repair_fallbacks"],
        "touched_p95": touched_p95,
        "scoped_mean_latency_s": scoped_lat,
        "full_mean_latency_s": full_lat,
        "replan_speedup": speedup,
        "gain_ratio_vs_cold": gain_ratio,
        "divergence_epsilon": cfg.divergence_epsilon,
        "slo_replacement_rate": slo_rate,
        "slo_sets_match": bool(slo_sets_match),
        "event_loop_errors": fleet.stats["errors"],
    }
    res["pass"] = bool(
        touched_p95 <= SCALE_TOUCHED_P95
        and speedup >= SCALE_SPEEDUP
        and gain_ratio >= 1.0 - cfg.divergence_epsilon
        and slo_rate == 1.0 and slo_sets_match
        and fleet.stats["errors"] == 0)
    return res


# ------------------------------------------------------------------ #
def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: same deterministic traces; writes "
                         "BENCH_fleet.json unless --json overrides it")
    ap.add_argument("--json", type=str, default=None,
                    help="write a machine-readable result summary to this "
                         "path (implied as BENCH_fleet.json by --quick)")
    args = ap.parse_args(argv)
    dev = TPU_V5E

    print("== recovery (device kill) ==")
    recovery = bench_recovery(dev)
    print(f"  SLO re-placement rate: {recovery['slo_replacement_rate']:.0%}")
    print(f"  evictions: {recovery['evictions']} "
          f"(all recorded: {recovery['evictions_recorded']})")
    print(f"  recovery latency: {recovery['recovery_latency_s']:.1f}s "
          f"virtual (kill -> all SLO re-placed)")
    print(f"  online == cold over survivors @1e-9: "
          f"{recovery['online_equals_cold']}")
    print(f"  event-loop errors: {recovery['event_loop_errors']}")

    print("== admission (arrival storm) ==")
    admission = bench_admission(dev)
    print(f"  storm of {admission['storm_size']}: "
          f"{admission['rejected']} rejected with records, "
          f"{admission['tracked_after_storm']} tracked "
          f"(bound {admission['tracked_bound']})")
    print(f"  replans for the storm: {admission['storm_replans']} "
          f"(batched admission; was one per arrival)")

    print("== straggler (slow device) ==")
    straggler = bench_straggler(dev)
    print(f"  device states: {straggler['device_states']}")
    print(f"  SLO on degraded device: "
          f"{straggler['slo_on_degraded_device'] or 'none'}")

    print("== scale (scoped repair, 256 heterogeneous devices) ==")
    scale = bench_scale()
    print(f"  fleet: {scale['devices']} devices "
          f"({'/'.join(scale['device_models'])}), "
          f"{scale['workloads_final']} tenants after "
          f"{scale['churn_mutations']} churn mutations")
    print(f"  repairs: {scale['scoped_repairs']} scoped, "
          f"{scale['full_replays']} full "
          f"({scale['repair_fallbacks']} fallbacks); "
          f"touched p95 {scale['touched_p95']:.0f} devices "
          f"(gate <= {SCALE_TOUCHED_P95:.0f})")
    print(f"  replan latency: {scale['scoped_mean_latency_s'] * 1e3:.1f} ms "
          f"scoped vs {scale['full_mean_latency_s'] * 1e3:.1f} ms full "
          f"-> {scale['replan_speedup']:.0f}x "
          f"(gate >= {SCALE_SPEEDUP:.0f}x)")
    print(f"  divergence: gain ratio vs cold "
          f"{scale['gain_ratio_vs_cold']:.4f} "
          f"(gate >= {1.0 - scale['divergence_epsilon']:.2f}); "
          f"SLO sets match: {scale['slo_sets_match']}")
    print(f"  SLO placement rate: {scale['slo_replacement_rate']:.0%}; "
          f"event-loop errors: {scale['event_loop_errors']}")

    print("\n== acceptance ==")
    for name, r in (("recovery", recovery), ("admission", admission),
                    ("straggler", straggler), ("scale", scale)):
        print(f"  {name}: {'PASS' if r['pass'] else 'FAIL'}")
    ok = (recovery["pass"] and admission["pass"] and straggler["pass"]
          and scale["pass"])

    json_path = args.json or ("BENCH_fleet.json" if args.quick else None)
    if json_path:
        payload = {
            "recovery": recovery,
            "admission": admission,
            "straggler": straggler,
            "scale": scale,
            "acceptance": {"recovery": recovery["pass"],
                           "admission": admission["pass"],
                           "straggler": straggler["pass"],
                           "scale": scale["pass"],
                           "all": ok},
        }
        Path(json_path).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"\n  wrote {json_path}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
